"""Deterministic simulation harness for the ACAR serving scheduler.

Three pieces:

* a **seeded synthetic-workload generator** — draws task streams from
  the calibrated paper suite (optionally with duplicate resubmissions,
  which exercise the scheduler's probe cache), fully reproducible from
  a seed;
* an **equivalence checker** — drives the same workload through the
  sequential ``ACAROrchestrator`` and the ``ContinuousBatchingScheduler``
  and checks, per task: identical routing mode, identical final answer,
  identical trace record hash — and globally: both artifact hash
  chains verify, the chain heads are byte-identical (batching may not
  perturb the audit trail), and the scheduler's ``logical_time`` is the
  total order of admission;
* an **engine-compaction checker** — drives the same task stream
  through the real-model ``BatchedACAREngine`` twice, once compacted
  (shared-prefix probe prefill + escalated-subset ensemble decodes)
  and once masked (tiled probe expansion + full-batch decodes), and
  checks per task: identical sigma, mode, final answer, per-member
  answers, and trace record hash — and globally: both artifact chains
  verify with byte-identical heads. Compaction must be an execution
  strategy, not a semantic change;
* a **paged-KV checker** (``--paged-kv``) — drives a duplicate-bearing
  task stream through the real-model engine twice, once on the dense
  ``tile_cache`` path and once on the paged KV subsystem (page pool +
  block tables + ref-counted prefix sharing + probe->ensemble prefill
  reuse), and applies the same per-task and audit-chain checks. The
  ensemble mirrors the paper's arena: its third member *is* the probe
  model, so probe prefill pages genuinely seed ensemble prefill.
  Paging must be an allocation strategy, not a semantic change;
* a **step-loop checker** (``--step-loop``) — drives a duplicate-bearing
  stream of long prompts (straddling multiple prefill chunks) through
  the paged engine twice, once wave-lockstep (``run_queued``) and once
  through the step-level continuous-batching loop (``run_stepped``:
  streaming admission off ``AdmissionQueue.ready()``, chunked prefill,
  mixed-phase bucketed decode steps, mid-stream retirement), and
  applies the same per-task and audit-chain checks. Iteration-level
  scheduling must be an execution strategy, not a semantic change;
* a **megastep checker** (``--megastep``) — serves the same stream
  through the step loop with megastep K=1 (per-tick baseline) and
  with K in {4, 16} fused decode ticks (one device-resident
  ``lax.scan`` launch per decode group, lane logits never touching
  the host), on both the single-device and the mesh-sharded loop,
  and applies the same per-task and audit-chain checks. The fusion
  depth must be a pure performance knob, not a semantic change;
* a **crash-recovery checker** (``--crash`` / ``--crash-at N``) —
  journals a step-loop run, kills it at chosen ticks (including one
  kill *mid-journal-append*, leaving a torn final line, and one kill
  on the data-parallel mesh), recovers each from the write-ahead
  journal on a fresh engine, and applies the same per-task and
  audit-chain checks against an uninterrupted run. A crash must be
  invisible in the audit trail: retired rows are restored verbatim,
  unfinished rows re-execute from their original admission indices;
* a **2-D mesh checker** (``--mesh2d``) — serves a mixed dense+MoE
  fleet (the MoE member using the capacity-free gather dispatch)
  through the step loop single-device and on the 2-D
  ("data", "model") serving mesh — rows placed over data shards,
  member params column-sharded and per-shard KV pages carrying only
  the local kv-head slice over model shards — and applies the same
  per-task and audit-chain checks, including megastep (fixed K and
  ``--megastep auto``) and kill->journal-recover legs. Tensor
  parallelism must be an execution substrate, not a semantic change;
* a **degraded-fleet checker** (``--faults``) — serves the stream on
  the sharded loop under a seeded fault plan (transient member-launch
  failure, NaN quarantine of both arena-lite members, a shard loss)
  and checks that shard loss alone preserves outcomes bit-identically,
  that the full degraded run replays identically (outcomes and fault
  events), that every admitted task still gets an answer, and that
  every degradation decision is a hashed record in a verifiable
  artifact chain.

Run standalone:

    PYTHONPATH=src:tests python tests/harness/simulate.py \
        --tasks 200 --seed 0 --batch-size 8 \
        [--engine-compaction] [--paged-kv] [--paged-only] \
        [--step-loop] [--step-only] [--sharded] [--sharded-only] \
        [--megastep] [--megastep-only] [--crash] [--crash-only] \
        [--crash-at N] [--faults] [--faults-only] \
        [--mesh2d] [--mesh2d-only]
"""
from __future__ import annotations

import argparse
import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.acar import ACARConfig
from repro.core.backends import GenResult, paper_backends
from repro.core.orchestrator import ACAROrchestrator, TaskOutcome
from repro.data.tasks import Task, paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.teamllm.artifacts import ArtifactStore


# ----------------------------------------------------------------------
# scripted backend: exact control over probe/ensemble answers, for
# sigma edge-case tests
# ----------------------------------------------------------------------
@dataclass
class ScriptedBackend:
    """Deterministic backend returning scripted answers.

    ``script`` maps (task_id, sample_idx) -> semantic answer; missing
    keys fall back to ``default``. Pure function of its inputs, so it
    is safe to share between the sequential and batched paths.
    """
    name: str
    script: Dict[Tuple[str, int], str] = field(default_factory=dict)
    default: str = "a"
    cost: float = 0.001
    latency_ms: float = 100.0

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int = 0, seed: int = 0,
                 **_kw) -> GenResult:
        ans = self.script.get((task.task_id, sample_idx), self.default)
        return GenResult(response=f"answer: {ans}",
                         semantic_answer=ans, cost=self.cost,
                         latency_ms=self.latency_ms, score=0.0)


def scripted_task(task_id: str = "t0", gold: str = "a") -> Task:
    return Task(task_id=task_id, benchmark="scripted",
                kind="reasoning", text=f"scripted task {task_id}",
                gold=gold, difficulty=0.0)


# ----------------------------------------------------------------------
# workload generation
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class WorkloadConfig:
    n_tasks: int = 200
    seed: int = 0
    # probability a request resubmits an earlier task (probe-cache
    # traffic); 0 disables duplicates
    duplicate_rate: float = 0.15


def generate_workload(cfg: WorkloadConfig) -> List[Task]:
    """Seeded synthetic request stream over the calibrated paper mix."""
    pool = paper_suite(seed=cfg.seed)
    rng = np.random.default_rng(cfg.seed + 0x5EED)
    stream: List[Task] = []
    for _ in range(cfg.n_tasks):
        if stream and rng.random() < cfg.duplicate_rate:
            stream.append(stream[int(rng.integers(len(stream)))])
        else:
            stream.append(pool[int(rng.integers(len(pool)))])
    return stream


# ----------------------------------------------------------------------
# equivalence checking
# ----------------------------------------------------------------------
@dataclass
class EquivalenceReport:
    n_tasks: int
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    hash_mismatches: List[str]
    sequential_chain_ok: bool
    scheduler_chain_ok: bool
    chain_heads_equal: bool
    logical_time_ok: bool
    probe_cache_hits: int
    speedup_vs_sequential: float

    @property
    def ok(self) -> bool:
        return (not self.mode_mismatches
                and not self.answer_mismatches
                and not self.hash_mismatches
                and self.sequential_chain_ok
                and self.scheduler_chain_ok
                and self.chain_heads_equal
                and self.logical_time_ok)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.sequential_chain_ok and self.scheduler_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"logical_time_ok={self.logical_time_ok} "
                f"cache_hits={self.probe_cache_hits} "
                f"speedup={self.speedup_vs_sequential:.2f}x "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_equivalence(tasks: Sequence[Task],
                    acfg: ACARConfig = ACARConfig(),
                    policy: MicroBatchPolicy = MicroBatchPolicy(),
                    workdir: Optional[Path] = None,
                    run_id: str = "sim",
                    overlap: bool = True,
                    backends_factory=paper_backends,
                    probe_name: str = "gemini-2.0-flash"
                    ) -> Tuple[EquivalenceReport,
                               List[TaskOutcome], List[TaskOutcome]]:
    """Drive ``tasks`` through both execution paths and compare."""
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-sim-"))
    workdir = Path(workdir)

    seq_backs = backends_factory()
    seq_store = ArtifactStore(workdir / "sequential.jsonl")
    seq = ACAROrchestrator(acfg, seq_backs[probe_name], seq_backs,
                           store=seq_store, run_id=run_id
                           ).run_suite(tasks)

    sched_backs = backends_factory()
    sched_store = ArtifactStore(workdir / "scheduler.jsonl")
    sched = ContinuousBatchingScheduler(
        acfg, sched_backs[probe_name], sched_backs, store=sched_store,
        run_id=run_id, policy=policy, overlap=overlap)
    bat = sched.serve(tasks)

    mode_mm, ans_mm, hash_mm = [], [], []
    for a, b in zip(seq, bat):
        tid = a.trace.task_id
        if a.trace.mode != b.trace.mode:
            mode_mm.append(
                f"{tid}: {a.trace.mode} != {b.trace.mode}")
        if a.trace.final_answer != b.trace.final_answer:
            ans_mm.append(
                f"{tid}: {a.trace.final_answer!r} != "
                f"{b.trace.final_answer!r}")
        if a.trace.record_hash() != b.trace.record_hash():
            hash_mm.append(tid)

    seq_audit = ArtifactStore(workdir / "sequential.jsonl").audit()
    sched_audit = ArtifactStore(workdir / "scheduler.jsonl").audit()
    lt = [o.trace.logical_time for o in bat]
    admitted = [o.trace.schedule["admitted"] for o in bat]
    logical_time_ok = lt == list(range(len(bat))) and lt == admitted

    report = EquivalenceReport(
        n_tasks=len(tasks),
        mode_mismatches=mode_mm,
        answer_mismatches=ans_mm,
        hash_mismatches=hash_mm,
        sequential_chain_ok=bool(seq_audit["ok"]),
        scheduler_chain_ok=bool(sched_audit["ok"]),
        chain_heads_equal=seq_audit["head"] == sched_audit["head"],
        logical_time_ok=logical_time_ok,
        probe_cache_hits=sched.cache.hits,
        speedup_vs_sequential=sched.stats.speedup_vs_sequential,
    )
    return report, seq, bat


# ----------------------------------------------------------------------
# engine compaction equivalence (real JAX models)
# ----------------------------------------------------------------------
def tiny_zoo(n_models: int = 4, arch: str = "smollm-135m",
             seed: int = 0):
    """Reduced dense zoo models with random params — enough to drive
    the full probe -> sigma -> route -> compacted-ensemble -> judge
    path bit-reproducibly without training."""
    import jax
    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.serving import ZooModel

    zoo = []
    for i in range(n_models):
        cfg = get_config(arch, reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(seed + i))
        zoo.append(ZooModel(name=f"m{i}", cfg=cfg, params=prm))
    return zoo


@dataclass
class EngineCompactionReport:
    n_tasks: int
    sigma_mismatches: List[str]
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    member_mismatches: List[str]
    hash_mismatches: List[str]
    compact_chain_ok: bool
    masked_chain_ok: bool
    chain_heads_equal: bool
    ensemble_decode_token_reduction: float
    probe_prefill_reduction: float

    @property
    def ok(self) -> bool:
        return (not self.sigma_mismatches
                and not self.mode_mismatches
                and not self.answer_mismatches
                and not self.member_mismatches
                and not self.hash_mismatches
                and self.compact_chain_ok
                and self.masked_chain_ok
                and self.chain_heads_equal)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} "
                f"sigma_mismatches={len(self.sigma_mismatches)} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"member_mismatches={len(self.member_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.compact_chain_ok and self.masked_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"decode_token_reduction="
                f"{self.ensemble_decode_token_reduction:.2f}x "
                f"prefill_reduction={self.probe_prefill_reduction:.2f}x "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def _engine_traces(run_id: str, tasks, res, member_names,
                   store: "ArtifactStore"):
    """Materialise one TraceRecord per served task from a
    QueuedServeResult, so compacted and masked engine runs can be
    compared through the same hash-chained audit trail the scheduler
    uses. Probe samples, member answers, sigma, mode, and the final
    answer — exactly the judge-visible state — are hashed."""
    from repro.core.extract import extract
    from repro.core.sigma import MODE_NAMES
    from repro.teamllm.fingerprint import prompt_hash, render_prompt
    from repro.teamllm.trace import ModelResponse, ProbeSample, \
        TraceRecord

    traces = []
    for i, task in enumerate(tasks):
        probe_samples = tuple(
            ProbeSample(response=txt,
                        answer=extract(txt, task.kind), cost=0.0)
            for txt in res.probe_texts[i])
        responses = tuple(
            ModelResponse(model=member_names[mi], response="",
                          answer=a, cost=0.0)
            for mi, a in enumerate(res.member_answers[i])
            if a is not None)
        prompt = render_prompt(task.text)
        final = res.final_answers[i]
        trace = TraceRecord(
            run_id=run_id, task_id=task.task_id,
            benchmark=task.benchmark,
            prompt_hash=prompt_hash(prompt),
            seed=0, sigma=float(res.sigma[i]),
            mode=MODE_NAMES[int(res.modes[i])],
            probe_samples=probe_samples, responses=responses,
            final_answer=final, correct=final == task.gold, cost=0.0,
            logical_time=i)
        store.append(trace)
        traces.append(trace)
    return traces


def _compare_engine_runs(tasks, res_a, res_b, member_names,
                         workdir: Path, run_id: str,
                         names: Tuple[str, str]):
    """Field-by-field and audit-chain comparison of two
    QueuedServeResults over the same task stream. Returns the five
    mismatch lists plus both audits."""
    store_a = ArtifactStore(workdir / f"{names[0]}.jsonl")
    store_b = ArtifactStore(workdir / f"{names[1]}.jsonl")
    traces_a = _engine_traces(run_id, tasks, res_a, member_names,
                              store_a)
    traces_b = _engine_traces(run_id, tasks, res_b, member_names,
                              store_b)

    sig_mm, mode_mm, ans_mm, mem_mm, hash_mm = [], [], [], [], []
    for i, task in enumerate(tasks):
        tid = task.task_id
        if float(res_a.sigma[i]) != float(res_b.sigma[i]):
            sig_mm.append(
                f"{tid}: {res_a.sigma[i]} != {res_b.sigma[i]}")
        if int(res_a.modes[i]) != int(res_b.modes[i]):
            mode_mm.append(
                f"{tid}: {res_a.modes[i]} != {res_b.modes[i]}")
        if res_a.final_answers[i] != res_b.final_answers[i]:
            ans_mm.append(
                f"{tid}: {res_a.final_answers[i]!r} != "
                f"{res_b.final_answers[i]!r}")
        if res_a.member_answers[i] != res_b.member_answers[i]:
            mem_mm.append(
                f"{tid}: {res_a.member_answers[i]} != "
                f"{res_b.member_answers[i]}")
        if traces_a[i].record_hash() != traces_b[i].record_hash():
            hash_mm.append(tid)

    audit_a = ArtifactStore(workdir / f"{names[0]}.jsonl").audit()
    audit_b = ArtifactStore(workdir / f"{names[1]}.jsonl").audit()
    return (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
            audit_b)


def run_engine_compaction_equivalence(
        tasks=None, n_tasks: int = 16, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 4,
        probe_temperature: float = 0.9,
        workdir: Optional[Path] = None,
        route_fn=None) -> EngineCompactionReport:
    """Serve the same stream through the compacted and the masked
    engine and compare every judge-visible output plus the audit
    chain. ``route_fn`` overrides sigma->mode routing (tests force
    exact escalation rates with it)."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-compact-"))
    workdir = Path(workdir)
    if tasks is None:
        from repro.data.tasks import arithmetic_suite
        tasks = arithmetic_suite(n_tasks, seed=seed)
    tasks = list(tasks)

    zoo = tiny_zoo(seed=seed)
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    compact_eng = BatchedACAREngine(
        acfg, zoo[0], zoo[1:], max_new_tokens=max_new_tokens,
        compact=True, shared_prefix=True, route_fn=route_fn)
    masked_eng = BatchedACAREngine(
        acfg, zoo[0], zoo[1:], max_new_tokens=max_new_tokens,
        compact=False, shared_prefix=False, route_fn=route_fn)
    res_c = compact_eng.run_queued(tasks, policy)
    res_m = masked_eng.run_queued(tasks, policy)

    member_names = [m.name for m in compact_eng.ensemble]
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_c,
     audit_m) = _compare_engine_runs(
        tasks, res_c, res_m, member_names, workdir, "compact",
        ("compacted", "masked"))
    cs = res_c.compaction
    return EngineCompactionReport(
        n_tasks=len(tasks),
        sigma_mismatches=sig_mm, mode_mismatches=mode_mm,
        answer_mismatches=ans_mm, member_mismatches=mem_mm,
        hash_mismatches=hash_mm,
        compact_chain_ok=bool(audit_c["ok"]),
        masked_chain_ok=bool(audit_m["ok"]),
        chain_heads_equal=audit_c["head"] == audit_m["head"],
        ensemble_decode_token_reduction=(
            cs.ensemble_decode_token_reduction if cs else 1.0),
        probe_prefill_reduction=(
            cs.probe_prefill_reduction if cs else 1.0))


# ----------------------------------------------------------------------
# paged-KV equivalence (real JAX models, page pool vs dense caches)
# ----------------------------------------------------------------------
@dataclass
class PagedKVReport:
    n_tasks: int
    sigma_mismatches: List[str]
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    member_mismatches: List[str]
    hash_mismatches: List[str]
    dense_chain_ok: bool
    paged_chain_ok: bool
    chain_heads_equal: bool
    # measured paged-KV accounting (probe model's server)
    kv_pages_highwater: int
    probe_memory_reduction: float     # dense tile_cache bytes / paged
    prefill_tokens_reused: int        # probe->ensemble + prefix cache
    prefill_tokens_reused_probe: int  # probe->ensemble seeding only

    @property
    def ok(self) -> bool:
        return (not self.sigma_mismatches
                and not self.mode_mismatches
                and not self.answer_mismatches
                and not self.member_mismatches
                and not self.hash_mismatches
                and self.dense_chain_ok
                and self.paged_chain_ok
                and self.chain_heads_equal)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} "
                f"sigma_mismatches={len(self.sigma_mismatches)} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"member_mismatches={len(self.member_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.dense_chain_ok and self.paged_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"kv_pages_hw={self.kv_pages_highwater} "
                f"probe_mem_reduction="
                f"{self.probe_memory_reduction:.2f}x "
                f"prefill_reused={self.prefill_tokens_reused} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def paged_workload(n_tasks: int, seed: int = 0,
                   duplicate_rate: float = 0.15) -> List[Task]:
    """Uniform-prompt arithmetic stream with duplicate resubmissions —
    duplicates exercise the cross-request prefix page cache the same
    way ``generate_workload`` exercises the scheduler's probe cache."""
    from repro.data.tasks import arithmetic_suite
    pool = arithmetic_suite(max(16, n_tasks // 2), seed=seed)
    rng = np.random.default_rng(seed + 0x9A6ED)
    stream: List[Task] = []
    for _ in range(n_tasks):
        if stream and rng.random() < duplicate_rate:
            stream.append(stream[int(rng.integers(len(stream)))])
        else:
            stream.append(pool[int(rng.integers(len(pool)))])
    return stream


def paged_zoo(seed: int = 0):
    """Probe + three ensemble members, the third being the probe model
    itself — mirroring the paper's arena (ARENA3 contains the probe),
    so probe->ensemble prefill-page reuse is genuinely sound and
    genuinely exercised."""
    from repro.serving import ZooModel
    zoo = tiny_zoo(3, seed=seed)
    probe = zoo[0]
    ensemble = [zoo[1], zoo[2],
                ZooModel(name="m3-probe", cfg=probe.cfg,
                         params=probe.params)]
    return probe, ensemble


def run_paged_kv_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 4,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> PagedKVReport:
    """Serve the same stream through the paged and the dense engine and
    compare every judge-visible output plus the audit chain. Paging —
    page pool, block tables, prefix sharing, COW forks, probe->ensemble
    prefill seeding, the prompt prefix cache — must be an allocation
    strategy, not a semantic change."""
    from repro.configs.acar import ACARConfig
    from repro.serving import (
        BatchedACAREngine, MicroBatchPolicy, dense_tile_slots)

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-paged-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = paged_workload(n_tasks, seed=seed,
                               duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    dense_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        compact=True, shared_prefix=True, paged=False,
        route_fn=route_fn)
    paged_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        compact=True, shared_prefix=True, paged=True,
        route_fn=route_fn)
    res_d = dense_eng.run_queued(tasks, policy)
    res_p = paged_eng.run_queued(tasks, policy)

    member_names = [m.name for m in ensemble]
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_d,
     audit_p) = _compare_engine_runs(
        tasks, res_d, res_p, member_names, workdir, "paged",
        ("dense", "paged"))

    kv = paged_eng.kv_stats()
    probe_kv = kv[probe.name]
    from repro.data import tokenizer as tok
    s = tok.encode_aligned([tasks[0].text]).shape[1]
    # dense probe high-water: tile_cache materialises B*N rows of
    # (prompt+new) slots at the same per-token bytes the pages use
    token_bytes = probe_kv.page_bytes / probe_kv.page_size
    dense_bytes = dense_tile_slots(
        batch_size, acfg.n_probe_samples, s, max_new_tokens) \
        * token_bytes
    paged_bytes = max(probe_kv.probe_highwater_bytes, 1)
    reused = sum(st.prefill_tokens_reused for st in kv.values())
    reused_probe = sum(st.prefill_tokens_reused_probe
                       for st in kv.values())
    return PagedKVReport(
        n_tasks=len(tasks),
        sigma_mismatches=sig_mm, mode_mismatches=mode_mm,
        answer_mismatches=ans_mm, member_mismatches=mem_mm,
        hash_mismatches=hash_mm,
        dense_chain_ok=bool(audit_d["ok"]),
        paged_chain_ok=bool(audit_p["ok"]),
        chain_heads_equal=audit_d["head"] == audit_p["head"],
        kv_pages_highwater=probe_kv.pages_highwater,
        probe_memory_reduction=dense_bytes / paged_bytes,
        prefill_tokens_reused=reused,
        prefill_tokens_reused_probe=reused_probe)


# ----------------------------------------------------------------------
# step-loop equivalence (wave-lockstep vs step-level continuous batching)
# ----------------------------------------------------------------------
@dataclass
class StepLoopReport:
    n_tasks: int
    sigma_mismatches: List[str]
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    member_mismatches: List[str]
    hash_mismatches: List[str]
    wave_chain_ok: bool
    step_chain_ok: bool
    chain_heads_equal: bool
    # step-loop accounting
    prompt_len: int
    chunk_tokens: int
    prefill_chunks: int
    step_ticks: int
    step_kv_highwater: int
    wave_kv_highwater: int

    @property
    def ok(self) -> bool:
        return (not self.sigma_mismatches
                and not self.mode_mismatches
                and not self.answer_mismatches
                and not self.member_mismatches
                and not self.hash_mismatches
                and self.wave_chain_ok
                and self.step_chain_ok
                and self.chain_heads_equal)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} "
                f"sigma_mismatches={len(self.sigma_mismatches)} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"member_mismatches={len(self.member_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.wave_chain_ok and self.step_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"prompt_len={self.prompt_len} "
                f"chunks/prompt={-(-self.prompt_len // self.chunk_tokens)} "
                f"prefill_chunks={self.prefill_chunks} "
                f"ticks={self.step_ticks} "
                f"kv_hw step/wave={self.step_kv_highwater}/"
                f"{self.wave_kv_highwater} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def long_prompt_workload(n_tasks: int, prompt_chars: int = 24,
                         seed: int = 0,
                         duplicate_rate: float = 0.15) -> List[Task]:
    """Uniform long arithmetic-surface prompts with duplicate
    resubmissions — long enough that every prompt straddles several
    prefill chunks (and page boundaries), duplicates exercising the
    prompt prefix cache under streaming admission."""
    rng = np.random.default_rng(seed + 0x57E9)
    pool_size = max(16, n_tasks // 2)
    pool = []
    for i in range(pool_size):
        digits = "".join(str(rng.integers(10))
                         for _ in range(prompt_chars - 8))
        pool.append(Task(
            task_id=f"step-{i:05d}", benchmark="step_loop",
            kind="math", text=f"{digits} + 1 = ", gold="0",
            difficulty=0.0))
    stream: List[Task] = []
    for _ in range(n_tasks):
        if stream and rng.random() < duplicate_rate:
            stream.append(stream[int(rng.integers(len(stream)))])
        else:
            stream.append(pool[int(rng.integers(pool_size))])
    return stream


def run_step_loop_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> StepLoopReport:
    """Serve the same stream through the wave-lockstep engine and the
    step-level loop and compare every judge-visible output plus the
    audit chain. Step-level continuous batching — streaming admission,
    chunked prefill, mixed-phase decode steps, mid-stream retirement —
    must be an execution strategy, not a semantic change."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-step-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)
    from repro.data import tokenizer as tok
    prompt_len = int(tok.encode_aligned([tasks[0].text]).shape[1])
    assert prompt_len > chunk_tokens, \
        "workload prompts must straddle at least one chunk boundary"

    probe, ensemble = paged_zoo(seed=seed)
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    wave_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=route_fn)
    step_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=route_fn)
    res_w = wave_eng.run_queued(tasks, policy)
    res_s = step_eng.run_stepped(tasks, policy,
                                 chunk_tokens=chunk_tokens)

    member_names = [m.name for m in ensemble]
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_w,
     audit_s) = _compare_engine_runs(
        tasks, res_w, res_s, member_names, workdir, "steploop",
        ("wave", "step"))

    return StepLoopReport(
        n_tasks=len(tasks),
        sigma_mismatches=sig_mm, mode_mismatches=mode_mm,
        answer_mismatches=ans_mm, member_mismatches=mem_mm,
        hash_mismatches=hash_mm,
        wave_chain_ok=bool(audit_w["ok"]),
        step_chain_ok=bool(audit_s["ok"]),
        chain_heads_equal=audit_w["head"] == audit_s["head"],
        prompt_len=prompt_len, chunk_tokens=chunk_tokens,
        prefill_chunks=res_s.step.prefill_chunks,
        step_ticks=res_s.step.ticks,
        step_kv_highwater=step_eng.kv_stats()[
            probe.name].pages_highwater,
        wave_kv_highwater=wave_eng.kv_stats()[
            probe.name].pages_highwater)


# ----------------------------------------------------------------------
# sharded-serving equivalence (mesh-parallel step loop vs single device)
# ----------------------------------------------------------------------
@dataclass
class ShardedReport:
    n_tasks: int
    n_shards: int
    sigma_mismatches: List[str]
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    member_mismatches: List[str]
    hash_mismatches: List[str]
    single_chain_ok: bool
    sharded_chain_ok: bool
    chain_heads_equal: bool
    # sharded accounting
    single_ticks: int
    sharded_ticks: int
    placements: Dict[int, int]
    aggregate_pool_pages: int
    single_pool_pages: int

    @property
    def ok(self) -> bool:
        return (not self.sigma_mismatches
                and not self.mode_mismatches
                and not self.answer_mismatches
                and not self.member_mismatches
                and not self.hash_mismatches
                and self.single_chain_ok
                and self.sharded_chain_ok
                and self.chain_heads_equal)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} shards={self.n_shards} "
                f"sigma_mismatches={len(self.sigma_mismatches)} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"member_mismatches={len(self.member_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.single_chain_ok and self.sharded_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"ticks single/sharded="
                f"{self.single_ticks}/{self.sharded_ticks} "
                f"placements={[self.placements.get(k, 0) for k in range(self.n_shards)]} "
                f"pool_pages aggregate/single="
                f"{self.aggregate_pool_pages}/{self.single_pool_pages} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_sharded_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        n_shards: int = 4, probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> ShardedReport:
    """Serve the same duplicate-bearing long-prompt stream through the
    single-device step loop and the mesh-sharded loop (data=n_shards,
    per-shard paged KV pools, least-loaded placement, one shard_map'd
    program per tick) and compare every judge-visible output plus the
    audit chain. Sharding — placement, per-shard pools, shard-local
    free lists — must be an execution substrate, not a semantic
    change: per-row sampling key streams are keyed by *global*
    admission index, so the shard a row lands on can never change its
    sampled tokens. Requires ``n_shards`` visible devices (the CLI
    re-execs itself under ``--xla_force_host_platform_device_count``
    when needed)."""
    import jax

    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    if len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"sharded equivalence needs {n_shards} devices, have "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-shard-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    single_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=route_fn)
    sharded_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=route_fn)
    res_1 = single_eng.run_stepped(tasks, policy,
                                   chunk_tokens=chunk_tokens)
    res_n = sharded_eng.run_stepped(tasks, policy,
                                    chunk_tokens=chunk_tokens,
                                    data_shards=n_shards)

    member_names = [m.name for m in ensemble]
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_1,
     audit_n) = _compare_engine_runs(
        tasks, res_1, res_n, member_names, workdir, "sharded",
        ("single", "sharded"))

    placements = {
        k: int(res_n.metrics.get("acar_shard_placements_total",
                                 shard=str(k)))
        for k in range(n_shards)}
    probe_name = probe.name
    return ShardedReport(
        n_tasks=len(tasks), n_shards=n_shards,
        sigma_mismatches=sig_mm, mode_mismatches=mode_mm,
        answer_mismatches=ans_mm, member_mismatches=mem_mm,
        hash_mismatches=hash_mm,
        single_chain_ok=bool(audit_1["ok"]),
        sharded_chain_ok=bool(audit_n["ok"]),
        chain_heads_equal=audit_1["head"] == audit_n["head"],
        single_ticks=res_1.step.ticks,
        sharded_ticks=res_n.step.ticks,
        placements=placements,
        aggregate_pool_pages=res_n.kv[probe_name].pool_pages,
        single_pool_pages=res_1.kv[probe_name].pool_pages)


@dataclass
class MegastepReport:
    n_tasks: int
    ks: Tuple[int, ...]
    n_shards: Optional[int]
    mismatches: Dict[str, int]          # leg -> mismatch count vs K=1
    chains_ok: Dict[str, bool]          # leg -> both chains verify
    heads_equal: Dict[str, bool]        # leg -> chain heads identical
    masked_steps: Dict[str, int]
    launches: Dict[str, int]
    baseline_launches: int

    @property
    def ok(self) -> bool:
        return (all(v == 0 for v in self.mismatches.values())
                and all(self.chains_ok.values())
                and all(self.heads_equal.values()))

    def summary(self) -> str:
        legs = " ".join(
            f"{leg}[mismatches={self.mismatches[leg]} "
            f"chains_ok={self.chains_ok[leg]} "
            f"heads_equal={self.heads_equal[leg]} "
            f"launches={self.launches[leg]} "
            f"masked={self.masked_steps[leg]}]"
            for leg in self.mismatches)
        return (f"tasks={self.n_tasks} ks={list(self.ks)} "
                f"shards={self.n_shards or 0} "
                f"baseline_launches={self.baseline_launches} {legs} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_megastep_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        ks: Tuple[int, ...] = (4, 16),
        n_shards: Optional[int] = None,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> MegastepReport:
    """Serve the same duplicate-bearing long-prompt stream through the
    step loop with megastep K=1 (the per-tick baseline) and with each
    K in ``ks`` (K decode ticks fused into one device-resident
    ``lax.scan`` launch, lane logits never touching the host), and
    compare every judge-visible output plus the audit-chain record
    hashes and heads. Per-row sampling key streams are indexed by
    (global admission index, per-row step counter), so K must be a
    pure performance knob — bit-identical streams at any fusion
    depth. With ``n_shards`` set, the sweep also runs each K through
    the mesh-sharded loop (one shard_map'd megastep per group per
    tick) against the same single-device per-tick baseline."""
    import jax

    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    if n_shards and len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"sharded megastep equivalence needs {n_shards} devices, "
            f"have {len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-megastep-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _run(megastep, shards=None):
        eng = BatchedACAREngine(
            acfg, probe, ensemble, max_new_tokens=max_new_tokens,
            route_fn=route_fn)
        return eng.run_stepped(tasks, policy,
                               chunk_tokens=chunk_tokens,
                               data_shards=shards, megastep=megastep)

    res_base = _run(1)
    legs = [(f"K{k}", k, None) for k in ks]
    if n_shards:
        legs += [(f"K{k}-sh{n_shards}", k, n_shards) for k in ks]

    mismatches: Dict[str, int] = {}
    chains_ok: Dict[str, bool] = {}
    heads_equal: Dict[str, bool] = {}
    masked: Dict[str, int] = {}
    launches: Dict[str, int] = {}
    for leg, k, shards in legs:
        res_k = _run(k, shards)
        # one file pair per leg: ArtifactStore appends, so reusing the
        # baseline's file across legs would chain every leg together
        (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
         audit_b) = _compare_engine_runs(
            tasks, res_base, res_k, member_names, workdir,
            f"megastep-{leg}", (f"per-tick-vs-{leg}", leg))
        mismatches[leg] = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                          + len(mem_mm) + len(hash_mm))
        chains_ok[leg] = bool(audit_a["ok"]) and bool(audit_b["ok"])
        heads_equal[leg] = audit_a["head"] == audit_b["head"]
        masked[leg] = res_k.step.masked_decode_steps
        launches[leg] = res_k.step.launches

    return MegastepReport(
        n_tasks=len(tasks), ks=tuple(ks), n_shards=n_shards,
        mismatches=mismatches, chains_ok=chains_ok,
        heads_equal=heads_equal, masked_steps=masked,
        launches=launches,
        baseline_launches=res_base.step.launches)


# ----------------------------------------------------------------------
# crash-recovery equivalence (kill -> journal recover vs uninterrupted)
# ----------------------------------------------------------------------
@dataclass
class CrashRecoveryReport:
    """Per-leg outcome of kill -> recover -> compare-to-uninterrupted.
    Legs kill the run at different ticks (including mid-journal-append
    for the torn leg, and on the data-parallel mesh for the sharded
    leg); every leg must recover to byte-identical record hashes and
    chain heads, and legs past the midpoint must restore >0 rows
    verbatim from the journal."""
    n_tasks: int
    crashed: Dict[str, bool]
    restored: Dict[str, int]
    restore_required: Dict[str, bool]
    journal_records: Dict[str, int]
    torn_recovered: Dict[str, bool]
    mismatches: Dict[str, int]
    chains_ok: Dict[str, bool]
    heads_equal: Dict[str, bool]

    @property
    def ok(self) -> bool:
        return (all(self.crashed.values())
                and all(v == 0 for v in self.mismatches.values())
                and all(self.chains_ok.values())
                and all(self.heads_equal.values())
                and all(self.restored[leg] > 0
                        for leg, req in self.restore_required.items()
                        if req)
                and all(self.torn_recovered[leg]
                        for leg in self.torn_recovered
                        if leg.startswith("torn")))

    def summary(self) -> str:
        legs = " ".join(
            f"[{leg}: restored={self.restored[leg]}"
            f"{'*' if self.restore_required[leg] else ''} "
            f"journal={self.journal_records[leg]} "
            f"mismatches={self.mismatches[leg]} "
            f"chain_ok={self.chains_ok[leg]} "
            f"head_eq={self.heads_equal[leg]}]"
            for leg in self.crashed)
        return (f"tasks={self.n_tasks} crash-legs={len(self.crashed)} "
                f"{legs} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_crash_recovery_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        crash_at: Optional[int] = None,
        n_shards: Optional[int] = 4,
        workdir: Optional[Path] = None,
        route_fn=None) -> CrashRecoveryReport:
    """Kill a journaled step-loop run at chosen ticks (SimulatedCrash
    escapes the loop exactly like SIGKILL — nothing past the fsync'd
    journal survives), recover from the journal on a fresh engine,
    and compare every judge-visible output plus record hashes and
    artifact-chain heads against an uninterrupted run. Legs: two kill
    points single-device (midpoint and 3/4), one kill *mid-journal-
    append* (torn final line, exercising ArtifactStore's truncate-and-
    reverify recovery), and one kill on the ``data=n_shards`` mesh.
    ``crash_at`` pins every leg's kill tick instead. (The torn leg's
    kill fires on the first journal append at-or-after the pinned
    instant of the *virtual clock* — appends are stamped with
    ``now``, not the loop tick — so it generally kills earlier than
    the plain kill leg at the same number; both are deterministic.)"""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.faults import FaultPlan, SimulatedCrash
    from repro.serving.journal import StepJournal

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-crash-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _run(shards=None, **kw):
        eng = BatchedACAREngine(
            acfg, probe, ensemble, max_new_tokens=max_new_tokens,
            route_fn=route_fn)
        if "recover" in kw:
            return eng.recover(tasks, policy,
                               journal_path=kw["recover"],
                               chunk_tokens=chunk_tokens,
                               data_shards=shards)
        return eng.run_stepped(tasks, policy,
                               chunk_tokens=chunk_tokens,
                               data_shards=shards, **kw)

    base = _run()
    base_sh = _run(shards=n_shards) if n_shards else None

    pinned = crash_at is not None and crash_at >= 0
    if pinned:
        single_ticks = [(crash_at, True)]
        torn_tick = sh_tick = crash_at
    else:
        mid = max(1, base.step.ticks // 2)
        late = max(1, base.step.ticks * 3 // 4)
        # the midpoint leg may legitimately predate the first
        # retirement, so only the late legs require restored > 0
        single_ticks = [(mid, False)] if mid == late \
            else [(mid, False), (late, True)]
        torn_tick = late
        sh_tick = max(1, base_sh.step.ticks * 3 // 4) \
            if base_sh is not None else 0

    legs = [(f"kill@{t}", t, False, None, req)
            for t, req in single_ticks]
    legs.append((f"torn@{torn_tick}", torn_tick, True, None, pinned))
    if n_shards:
        legs.append((f"data{n_shards}@{sh_tick}", sh_tick, False,
                     n_shards, True))

    crashed, restored, required = {}, {}, {}
    records, torn_rec, mismatches = {}, {}, {}
    chains_ok, heads_equal = {}, {}
    for li, (leg, tick, torn, shards, req) in enumerate(legs):
        jp = workdir / f"journal-{li}.jsonl"
        crashed[leg] = False
        try:
            _run(shards=shards, journal_path=jp,
                 faults=FaultPlan.crash_at(tick, torn=torn))
        except SimulatedCrash:
            crashed[leg] = True
        state = StepJournal.load(jp)
        records[leg] = state.records
        torn_rec[leg] = state.torn_recovered
        res_r = _run(shards=shards, recover=jp)
        restored[leg] = res_r.restored_rows
        required[leg] = req
        ref = base_sh if shards else base
        (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
         audit_b) = _compare_engine_runs(
            tasks, ref, res_r, member_names, workdir,
            f"crash-{leg}", (f"uninterrupted-{li}", f"recovered-{li}"))
        mismatches[leg] = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                          + len(mem_mm) + len(hash_mm))
        chains_ok[leg] = bool(audit_a["ok"]) and bool(audit_b["ok"])
        heads_equal[leg] = audit_a["head"] == audit_b["head"]

    return CrashRecoveryReport(
        n_tasks=len(tasks), crashed=crashed, restored=restored,
        restore_required=required, journal_records=records,
        torn_recovered=torn_rec, mismatches=mismatches,
        chains_ok=chains_ok, heads_equal=heads_equal)


# ----------------------------------------------------------------------
# 2-D ("data", "model") mesh equivalence (tensor-parallel members +
# gather-MoE, vs single device)
# ----------------------------------------------------------------------
def mesh2d_zoo(seed: int = 0):
    """Probe + dense member + gather-MoE member + probe-reuse member,
    every config tensor-parallel capable (heads, kv heads, d_ff and
    the MoE expert width all divisible by the model-axis size 2).

    Mirrors ``paged_zoo``'s arena shape — the last ensemble member
    *is* the probe model, so COW page forks are exercised on the 2-D
    mesh too — and adds a capacity-free gather-dispatch MoE member,
    the config class this mesh exists to serve (batch-invariant, so
    it takes the compacted escalated-subset path like any dense
    member)."""
    import jax

    from repro.configs.base import MoEConfig
    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.serving import ZooModel

    base = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True, num_heads=4, num_kv_heads=2, head_dim=16)
    moe = base.replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                      impl="gather", first_moe_layer=0))
    cfgs = [base, base, moe]
    zoo = [ZooModel(name=f"m{i}", cfg=c,
                    params=params_lib.init_params(
                        c, jax.random.PRNGKey(seed + i)))
           for i, c in enumerate(cfgs)]
    probe = zoo[0]
    ensemble = [zoo[1], zoo[2],
                ZooModel(name="m3-probe", cfg=probe.cfg,
                         params=probe.params)]
    return probe, ensemble


@dataclass
class Mesh2dReport:
    n_tasks: int
    data_shards: int
    model_shards: int
    mismatches: Dict[str, int]          # leg -> mismatch count vs base
    chains_ok: Dict[str, bool]
    heads_equal: Dict[str, bool]
    crashed: bool                       # crash leg really got killed
    restored_rows: int
    single_ticks: int
    mesh_ticks: int
    placements: Dict[int, int]          # data-shard -> rows placed
    steals: int                         # work-steal re-placements
    masked_steps: Dict[str, int]        # megastep legs' masked budget

    @property
    def ok(self) -> bool:
        return (all(v == 0 for v in self.mismatches.values())
                and all(self.chains_ok.values())
                and all(self.heads_equal.values())
                and self.crashed)

    def summary(self) -> str:
        legs = " ".join(
            f"{leg}[mismatches={self.mismatches[leg]} "
            f"chains_ok={self.chains_ok[leg]} "
            f"heads_equal={self.heads_equal[leg]}]"
            for leg in self.mismatches)
        return (f"tasks={self.n_tasks} "
                f"mesh=(data={self.data_shards},"
                f"model={self.model_shards}) "
                f"ticks single/mesh="
                f"{self.single_ticks}/{self.mesh_ticks} "
                f"placements={[self.placements.get(k, 0) for k in range(self.data_shards)]} "
                f"steals={self.steals} "
                f"masked={self.masked_steps} "
                f"crashed={self.crashed} restored={self.restored_rows} "
                f"{legs} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_mesh2d_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 4,
        data_shards: int = 2, model_shards: int = 2,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> Mesh2dReport:
    """Serve the same duplicate-bearing long-prompt stream through the
    single-device step loop and the 2-D (data, model) mesh loop over a
    mixed dense+MoE fleet, and compare every judge-visible output plus
    the audit chain. Legs: the base 2-D run, a fixed-K megastep run, a
    ``megastep="auto"`` run (per-group K capped at the group's minimum
    remaining budget), and a kill->journal-recover run on the mesh —
    all against the same single-device per-tick baseline. Placement
    stays keyed by global admission index and params/pages are
    column-sharded over the model axis, so neither the shard a row
    lands on nor the shard width may change a single sampled token.
    Requires ``data_shards * model_shards`` visible devices."""
    import jax

    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.faults import FaultPlan, SimulatedCrash
    from repro.serving.metrics import SHARD_STEALS

    need = data_shards * model_shards
    if len(jax.devices()) < need:
        raise RuntimeError(
            f"2-D mesh equivalence needs {need} devices, have "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-mesh2d-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = mesh2d_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _run(mesh=False, megastep=1, **kw):
        eng = BatchedACAREngine(
            acfg, probe, ensemble, max_new_tokens=max_new_tokens,
            route_fn=route_fn)
        shards = dict(data_shards=data_shards,
                      model_shards=model_shards) if mesh else {}
        if "recover" in kw:
            return eng.recover(tasks, policy,
                               journal_path=kw["recover"],
                               chunk_tokens=chunk_tokens,
                               megastep=megastep, **shards)
        return eng.run_stepped(tasks, policy,
                               chunk_tokens=chunk_tokens,
                               megastep=megastep, **shards, **kw)

    base = _run()

    mismatches: Dict[str, int] = {}
    chains_ok: Dict[str, bool] = {}
    heads_equal: Dict[str, bool] = {}
    masked: Dict[str, int] = {}

    def _compare(leg, res):
        (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
         audit_b) = _compare_engine_runs(
            tasks, base, res, member_names, workdir,
            f"mesh2d-{leg}", (f"single-vs-{leg}", leg))
        mismatches[leg] = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                          + len(mem_mm) + len(hash_mm))
        chains_ok[leg] = bool(audit_a["ok"]) and bool(audit_b["ok"])
        heads_equal[leg] = audit_a["head"] == audit_b["head"]

    res_mesh = _run(mesh=True)
    _compare("mesh2d", res_mesh)

    for leg, k in (("mesh2d-K4", 4), ("mesh2d-auto", "auto")):
        res_k = _run(mesh=True, megastep=k)
        _compare(leg, res_k)
        masked[leg] = res_k.step.masked_decode_steps

    # kill on the mesh at 3/4 of its uninterrupted run, recover on a
    # fresh engine with the same 2-D mesh shape
    tick = max(1, res_mesh.step.ticks * 3 // 4)
    jp = workdir / "journal-mesh2d.jsonl"
    crashed = False
    try:
        _run(mesh=True, journal_path=jp,
             faults=FaultPlan.crash_at(tick))
    except SimulatedCrash:
        crashed = True
    res_r = _run(mesh=True, recover=jp)
    _compare(f"recovered@{tick}", res_r)

    placements = {
        k: int(res_mesh.metrics.get("acar_shard_placements_total",
                                    shard=str(k)))
        for k in range(data_shards)}
    steals = int(sum(
        res_mesh.metrics.get(SHARD_STEALS, src=str(i), dst=str(j))
        for i in range(data_shards) for j in range(data_shards)
        if i != j))
    return Mesh2dReport(
        n_tasks=len(tasks), data_shards=data_shards,
        model_shards=model_shards,
        mismatches=mismatches, chains_ok=chains_ok,
        heads_equal=heads_equal, crashed=crashed,
        restored_rows=res_r.restored_rows,
        single_ticks=base.step.ticks,
        mesh_ticks=res_mesh.step.ticks,
        placements=placements, steals=steals,
        masked_steps=masked)


# ----------------------------------------------------------------------
# degraded-fleet serving (member quarantine + shard loss, fully traced)
# ----------------------------------------------------------------------
@dataclass
class DegradedFleetReport:
    """The fleet keeps serving through member quarantines and a shard
    loss: shard loss alone preserves outcomes bit-identically
    (restart-from-prefill replays the same admission-indexed key
    streams); the full degraded plan is deterministic (two runs with
    the same plan match on every judge-visible output and every fault
    event); and every degradation decision lands in a verifiable
    hash-chained artifact store."""
    n_tasks: int
    n_shards: int
    shard_loss_mismatches: int
    shard_loss_heads_equal: bool
    replay_mismatches: int
    replay_heads_equal: bool
    replay_faults_identical: bool
    all_answered: bool
    fault_kinds: Dict[str, int]
    fault_chain_ok: bool
    fault_chain_records: int
    degraded_routes: int
    quarantined_members: int

    @property
    def ok(self) -> bool:
        return (self.shard_loss_mismatches == 0
                and self.shard_loss_heads_equal
                and self.replay_mismatches == 0
                and self.replay_heads_equal
                and self.replay_faults_identical
                and self.all_answered
                and self.fault_chain_ok
                and self.fault_chain_records > 0
                and self.quarantined_members > 0
                and self.fault_kinds.get("shard_lost", 0) > 0)

    def summary(self) -> str:
        kinds = ",".join(f"{k}:{v}"
                         for k, v in sorted(self.fault_kinds.items()))
        return (f"tasks={self.n_tasks} shards={self.n_shards} "
                f"shard_loss_mismatches={self.shard_loss_mismatches} "
                f"replay_mismatches={self.replay_mismatches} "
                f"replay_faults_identical="
                f"{self.replay_faults_identical} "
                f"all_answered={self.all_answered} "
                f"fault_chain_ok={self.fault_chain_ok} "
                f"fault_records={self.fault_chain_records} "
                f"degraded_routes={self.degraded_routes} "
                f"quarantined={self.quarantined_members} "
                f"kinds=[{kinds}] "
                f"=> {'DETERMINISTIC' if self.ok else 'DIVERGENT'}")


def run_degraded_fleet(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        n_shards: int = 4,
        workdir: Optional[Path] = None,
        route_fn=None) -> DegradedFleetReport:
    """Serve the stream on the ``data=n_shards`` mesh under a fixed
    fault plan — a transient member-launch failure, NaN quarantines of
    both arena-lite members mid-stream, and a shard loss — and prove
    the three degraded-fleet properties (see DegradedFleetReport)."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.faults import FaultPlan, FaultSpec

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-faults-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _run(plan=None):
        eng = BatchedACAREngine(
            acfg, probe, ensemble, max_new_tokens=max_new_tokens,
            route_fn=route_fn)
        return eng.run_stepped(tasks, policy,
                               chunk_tokens=chunk_tokens,
                               data_shards=n_shards, faults=plan)

    base = _run()

    # leg 1: shard loss alone must preserve outcomes bit-identically
    loss_plan = FaultPlan(specs=(
        FaultSpec(tick=6, site="shard_loss", shard=1),))
    res_l = _run(loss_plan)
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
     audit_b) = _compare_engine_runs(
        tasks, base, res_l, member_names, workdir, "shard-loss",
        ("fault-free", "shard-loss"))
    loss_mm = (len(sig_mm) + len(mode_mm) + len(ans_mm)
               + len(mem_mm) + len(hash_mm))
    loss_heads = audit_a["head"] == audit_b["head"]

    # leg 2: full degraded plan, run twice — byte-identical replay
    plan = FaultPlan(specs=(
        FaultSpec(tick=2, site="member_launch",
                  model=member_names[0]),
        FaultSpec(tick=4, site="member_nan", model=member_names[0]),
        FaultSpec(tick=7, site="member_nan", model=member_names[1]),
        FaultSpec(tick=10, site="shard_loss", shard=2),
    ))
    res_a = _run(plan)
    res_b = _run(plan)
    (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
     audit_b) = _compare_engine_runs(
        tasks, res_a, res_b, member_names, workdir, "degraded",
        ("degraded-a", "degraded-b"))
    replay_mm = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                 + len(mem_mm) + len(hash_mm))
    replay_heads = audit_a["head"] == audit_b["head"]

    # leg 3: every degradation decision is a hashed record in a
    # verifiable artifact chain
    fstore = ArtifactStore(workdir / "fault-events.jsonl")
    for rec in (res_a.faults or []):
        fstore.append(rec)
    faudit = ArtifactStore(workdir / "fault-events.jsonl").audit()
    kinds: Dict[str, int] = {}
    for rec in (res_a.faults or []):
        kinds[rec["kind"]] = kinds.get(rec["kind"], 0) + 1

    from repro.serving.metrics import (
        MEMBER_QUARANTINED, ROUTES_DEGRADED)
    degraded = sum(
        int(res_a.metrics.get(ROUTES_DEGRADED,
                              **{"from": str(f), "to": str(t)}))
        for f in (1, 2) for t in (0, 1) if t < f)
    quarantined = sum(
        1 for m in member_names
        if res_a.metrics.get(MEMBER_QUARANTINED, model=m) > 0)

    return DegradedFleetReport(
        n_tasks=len(tasks), n_shards=n_shards,
        shard_loss_mismatches=loss_mm,
        shard_loss_heads_equal=loss_heads,
        replay_mismatches=replay_mm,
        replay_heads_equal=replay_heads,
        replay_faults_identical=res_a.faults == res_b.faults,
        all_answered=all(a is not None
                         for a in res_a.final_answers),
        fault_kinds=kinds, fault_chain_ok=bool(faudit["ok"]),
        fault_chain_records=int(faudit.get("records", 0)
                                or len(res_a.faults or [])),
        degraded_routes=degraded,
        quarantined_members=quarantined)


# ----------------------------------------------------------------------
# heterogeneous paged-state equivalence (quant KV pages, recurrent-state
# lanes, ring pages — mixed fleet vs the dense-cache baseline)
# ----------------------------------------------------------------------
def hetero_zoo(seed: int = 0):
    """Quant-KV probe + heterogeneous ensemble: a Mamba member paging
    its conv+SSM state as recurrent lanes, a sliding-window member on
    window-capped ring pages, and a probe-reuse member on int8 code
    pages — every page layout the stepped engine serves, in one
    arena. The probe-reuse member shares the probe's params, so quant
    probe pages genuinely seed ensemble decode."""
    import jax

    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.serving import ZooModel

    base = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    mamba = get_config("falcon-mamba-7b", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    cfgs = [("probe-q8", base.replace(kv_quant=True)),
            ("m1-mamba", mamba),
            ("m2-swa", base.replace(window=16))]
    zoo = [ZooModel(name=n, cfg=c,
                    params=params_lib.init_params(
                        c, jax.random.PRNGKey(seed + i)))
           for i, (n, c) in enumerate(cfgs)]
    probe = zoo[0]
    ensemble = [zoo[1], zoo[2],
                ZooModel(name="m3-probe", cfg=probe.cfg,
                         params=probe.params)]
    return probe, ensemble


def mamba_probe_zoo(seed: int = 0):
    """All-recurrent probe path: a Mamba probe (every probe row lives
    on recurrent-state lanes — prefill, N-sample fork, retirement)
    plus a dense member and a lane-reusing probe twin."""
    import jax

    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.serving import ZooModel

    base = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    mamba = get_config("falcon-mamba-7b", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    probe = ZooModel(name="probe-mamba", cfg=mamba,
                     params=params_lib.init_params(
                         mamba, jax.random.PRNGKey(seed)))
    ensemble = [
        ZooModel(name="m1-dense", cfg=base,
                 params=params_lib.init_params(
                     base, jax.random.PRNGKey(seed + 1))),
        ZooModel(name="m2-mamba", cfg=mamba,
                 params=params_lib.init_params(
                     mamba, jax.random.PRNGKey(seed + 2))),
        ZooModel(name="m3-probe", cfg=probe.cfg,
                 params=probe.params)]
    return probe, ensemble


@dataclass
class HeteroReport:
    """Heterogeneous paged state must be an allocation strategy, not a
    semantic change: every leg — stepped loop over mixed layouts, the
    quant-paged wave server, the data-parallel mesh, kill->recover,
    and the all-Mamba probe fleet — must match the dense-cache wave
    baseline on every judge-visible output and chain head."""
    n_tasks: int
    layouts: Dict[str, str]             # model name -> page layout
    mismatches: Dict[str, int]          # leg -> mismatch count vs base
    chains_ok: Dict[str, bool]
    heads_equal: Dict[str, bool]
    crashed: bool                       # crash leg really got killed
    restored_rows: int
    step_ticks: int
    quant_pages_highwater: int          # probe's int8 page high-water
    lanes_pages_highwater: int          # mamba-probe fleet lane usage
    ring_table_width: int               # SWA member, window-capped
    dense_table_width: int              # same row without the cap

    @property
    def ok(self) -> bool:
        return (all(v == 0 for v in self.mismatches.values())
                and all(self.chains_ok.values())
                and all(self.heads_equal.values())
                and self.crashed
                and self.quant_pages_highwater > 0
                and self.lanes_pages_highwater > 0
                and self.ring_table_width < self.dense_table_width)

    def summary(self) -> str:
        legs = " ".join(
            f"{leg}[mismatches={self.mismatches[leg]} "
            f"chains_ok={self.chains_ok[leg]} "
            f"heads_equal={self.heads_equal[leg]}]"
            for leg in self.mismatches)
        lay = ",".join(f"{k}:{v}"
                       for k, v in sorted(self.layouts.items()))
        return (f"tasks={self.n_tasks} layouts=[{lay}] "
                f"ticks={self.step_ticks} "
                f"quant_pages_hw={self.quant_pages_highwater} "
                f"lanes_pages_hw={self.lanes_pages_highwater} "
                f"ring_width={self.ring_table_width}/"
                f"{self.dense_table_width} "
                f"crashed={self.crashed} restored={self.restored_rows} "
                f"{legs} "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_hetero_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        n_shards: int = 4, probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        workdir: Optional[Path] = None,
        route_fn=None) -> HeteroReport:
    """Serve the same duplicate-bearing long-prompt stream through a
    heterogeneous fleet (quant-KV probe, Mamba lanes member, SWA ring
    member, quant probe-reuse member) on every execution substrate and
    compare each against the dense-cache wave baseline: the stepped
    loop (mixed page layouts in one tick), the quant-paged wave
    server, the ``data=n_shards`` mesh (quant rows sharded, ring/lanes
    members on the dense fallback), a kill->journal-recover leg, and
    an all-Mamba-probe fleet (every probe row prefilled, forked N
    ways and retired on recurrent-state lanes). Page layout must be
    an allocation strategy, not a semantic change."""
    import jax

    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.faults import FaultPlan, SimulatedCrash
    from repro.serving.journal import StepJournal
    from repro.serving.kv_pool import pages_for

    if n_shards and len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"hetero equivalence needs {n_shards} devices, have "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-hetero-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = hetero_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _engine(p, e, paged=True):
        return BatchedACAREngine(
            acfg, p, e, max_new_tokens=max_new_tokens, paged=paged,
            route_fn=route_fn)

    # the baseline is the *dense* cache path: dense int8 KV for the
    # quant models, the dense SSM cache for the Mamba member, the
    # dense ring buffer for the SWA member — paged must match it
    # bit-for-bit
    base = _engine(probe, ensemble, paged=False).run_queued(
        tasks, policy)

    mismatches: Dict[str, int] = {}
    chains_ok: Dict[str, bool] = {}
    heads_equal: Dict[str, bool] = {}

    def _compare(leg, ref, res, names):
        (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
         audit_b) = _compare_engine_runs(
            tasks, ref, res, names, workdir,
            f"hetero-{leg}", (f"dense-vs-{leg}", leg))
        mismatches[leg] = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                          + len(mem_mm) + len(hash_mm))
        chains_ok[leg] = bool(audit_a["ok"]) and bool(audit_b["ok"])
        heads_equal[leg] = audit_a["head"] == audit_b["head"]

    # leg 1: stepped loop, every layout live in the same ticks
    step_eng = _engine(probe, ensemble)
    res_s = step_eng.run_stepped(tasks, policy,
                                 chunk_tokens=chunk_tokens)
    _compare("step", base, res_s, member_names)

    # leg 2: wave loop on the quant-paged server (int8 code pages +
    # scale planes through probe_wave/reuse_decode)
    res_w = _engine(probe, ensemble).run_queued(tasks, policy)
    _compare("wave-paged", base, res_w, member_names)

    # leg 3: data-parallel mesh — quant probe rows sharded over
    # per-shard pools; ring/lanes members take the dense fallback
    if n_shards:
        res_n = _engine(probe, ensemble).run_stepped(
            tasks, policy, chunk_tokens=chunk_tokens,
            data_shards=n_shards)
        _compare(f"data{n_shards}", base, res_n, member_names)

    # leg 4: kill the journaled hetero run at 3/4, recover on a fresh
    # engine — recurrent lanes and ring pages must rebuild from the
    # journal exactly like dense pages do
    tick = max(1, res_s.step.ticks * 3 // 4)
    jp = workdir / "journal-hetero.jsonl"
    crashed = False
    try:
        _engine(probe, ensemble).run_stepped(
            tasks, policy, chunk_tokens=chunk_tokens, journal_path=jp,
            faults=FaultPlan.crash_at(tick))
    except SimulatedCrash:
        crashed = True
    StepJournal.load(jp)
    res_r = _engine(probe, ensemble).recover(
        tasks, policy, journal_path=jp, chunk_tokens=chunk_tokens)
    _compare(f"recovered@{tick}", base, res_r, member_names)

    # leg 5: all-Mamba probe fleet — probe prefill, N-sample fork and
    # retirement all live on recurrent-state lanes
    mprobe, mensemble = mamba_probe_zoo(seed=seed)
    mnames = [m.name for m in mensemble]
    mbase = _engine(mprobe, mensemble, paged=False).run_queued(
        tasks, policy)
    meng = _engine(mprobe, mensemble)
    res_m = meng.run_stepped(tasks, policy, chunk_tokens=chunk_tokens)
    _compare("mamba-step", mbase, res_m, mnames)

    from repro.data import tokenizer as tok
    from repro.models.transformer import resolve_layout
    s = int(tok.encode_aligned([tasks[0].text]).shape[1])
    layouts = {m.name: (resolve_layout(m.cfg) or "dense*")
               for m in [probe] + ensemble}
    swa = ensemble[1]
    srv_ring = step_eng._stepped_server(swa)
    ring_w = srv_ring.table_width(s, max_new_tokens)
    dense_w = pages_for(s + max_new_tokens, srv_ring.page_size)
    return HeteroReport(
        n_tasks=len(tasks), layouts=layouts,
        mismatches=mismatches, chains_ok=chains_ok,
        heads_equal=heads_equal, crashed=crashed,
        restored_rows=res_r.restored_rows,
        step_ticks=res_s.step.ticks,
        quant_pages_highwater=step_eng.kv_stats()[
            probe.name].pages_highwater,
        lanes_pages_highwater=meng.kv_stats()[
            mprobe.name].pages_highwater,
        ring_table_width=ring_w, dense_table_width=dense_w)


# ----------------------------------------------------------------------
# provenance-grade observability (span tracing + PROV + attribution)
# ----------------------------------------------------------------------
@dataclass
class ObsReport:
    """Span tracing must be a pure observer: arming the tracer cannot
    change a single judge-visible output, record hash, or artifact
    chain head, while the span chain itself must be deterministic,
    hash-verifiable, PROV-walkable for every retired task, and carry
    on-capacity leave-one-out attribution that matches the offline
    oracle exactly."""
    n_tasks: int
    # per-leg output/hash mismatch counts (traced vs untraced)
    mismatches: Dict[str, int]
    chains_ok: Dict[str, bool]
    heads_equal: Dict[str, bool]
    span_heads_deterministic: bool
    span_file_ok: bool
    span_records: int
    lineage_tasks: int
    lineage_failures: List[str]
    attribution_rows: int
    attribution_mismatches: List[str]
    crash_restored: int
    crash_restore_spans: int
    wave_spans: int

    @property
    def ok(self) -> bool:
        return (all(v == 0 for v in self.mismatches.values())
                and all(self.chains_ok.values())
                and all(self.heads_equal.values())
                and self.span_heads_deterministic
                and self.span_file_ok
                and not self.lineage_failures
                and self.attribution_rows > 0
                and not self.attribution_mismatches
                and self.crash_restored > 0
                and self.crash_restore_spans == self.crash_restored
                and self.wave_spans > 0)

    def summary(self) -> str:
        legs = " ".join(
            f"[{leg}: mismatches={self.mismatches[leg]} "
            f"chains={'ok' if self.chains_ok[leg] else 'BAD'} "
            f"heads={'=' if self.heads_equal[leg] else '!='}]"
            for leg in self.mismatches)
        return (
            f"observability: {self.n_tasks} tasks {legs} "
            f"| spans={self.span_records} "
            f"det={'yes' if self.span_heads_deterministic else 'NO'} "
            f"file={'ok' if self.span_file_ok else 'BAD'} "
            f"| lineage={self.lineage_tasks} walked, "
            f"{len(self.lineage_failures)} failures "
            f"| attribution={self.attribution_rows} rows, "
            f"{len(self.attribution_mismatches)} oracle mismatches "
            f"| crash: restored={self.crash_restored} "
            f"restore_spans={self.crash_restore_spans} "
            f"| wave spans={self.wave_spans} "
            f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def _attribution_oracle_check(tasks, res, member_names):
    """Compare every on-capacity ``attribution`` span against the
    offline ``core.attribution.leave_one_out`` oracle, row by row
    (exact float equality — both sides run the same judge)."""
    from repro.core.attribution import leave_one_out
    from repro.teamllm.trace import ModelResponse

    att_by_adm = {}
    for s in res.spans:
        if s["phase"] == "attribution":
            adm = int(s["trace"].rsplit("#", 1)[1])
            att_by_adm[adm] = s
    rows = 0
    mismatches = []
    for i, task in enumerate(tasks):
        if int(res.modes[i]) < 2:
            continue
        rows += 1
        span = att_by_adm.get(i)
        if span is None:
            mismatches.append(f"adm {i}: no attribution span")
            continue
        responses = [
            ModelResponse(model=member_names[mi], response="",
                          answer=a, cost=0.0)
            for mi, a in enumerate(res.member_answers[i])
            if a is not None]
        oracle = {m: float(v) for m, v in leave_one_out(
            responses, task.task_id, task.gold).items()}
        if span["values"] != oracle:
            mismatches.append(
                f"adm {i}: span {span['values']} != oracle {oracle}")
    # escalated rows with no span at all also surface above
    extra = set(att_by_adm) - {
        i for i in range(len(tasks)) if int(res.modes[i]) >= 2}
    for adm in sorted(extra):
        mismatches.append(f"adm {adm}: unexpected attribution span")
    return rows, mismatches


def run_obs_equivalence(
        tasks=None, n_tasks: int = 200, seed: int = 0,
        batch_size: int = 8, max_new_tokens: int = 6,
        prompt_chars: int = 24, chunk_tokens: int = 8,
        probe_temperature: float = 0.9,
        duplicate_rate: float = 0.15,
        n_shards: Optional[int] = 4,
        workdir: Optional[Path] = None,
        route_fn=None) -> ObsReport:
    """Prove the observability layer is provenance-grade and free:
    (1) arming a SpanTracer leaves the step loop bit-identical to the
    untraced run — judge-visible outputs, record hashes, artifact
    chain heads — on single-device, ``data=n_shards`` sharded, and
    crash→recover legs; (2) the span chain is deterministic (same
    head twice) and its flushed JSONL passes the ArtifactStore audit;
    (3) the PROV lineage walk verifies every span hash for every
    served task; (4) every escalated (full-arena) row's on-capacity
    ``attribution`` span equals the offline leave-one-out oracle
    exactly; (5) the recovered run re-materialises every restored row
    with a ``restore`` span (span continuity across the journal
    replay); (6) the wave engine's post-hoc spans cover the same
    lifecycle."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.faults import FaultPlan, SimulatedCrash
    from repro.serving.tracing import SpanTracer
    from repro.teamllm.prov import lineage, verify_span_file

    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-obs-"))
    workdir = Path(workdir)
    if tasks is None:
        tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                     duplicate_rate=duplicate_rate)
    tasks = list(tasks)

    probe, ensemble = paged_zoo(seed=seed)
    member_names = [m.name for m in ensemble]
    acfg = ACARConfig(probe_temperature=probe_temperature, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)

    def _run(tracer=None, shards=None, **kw):
        eng = BatchedACAREngine(
            acfg, probe, ensemble, max_new_tokens=max_new_tokens,
            route_fn=route_fn)
        if "recover" in kw:
            return eng.recover(tasks, policy,
                               journal_path=kw["recover"],
                               chunk_tokens=chunk_tokens,
                               data_shards=shards, tracer=tracer)
        return eng.run_stepped(tasks, policy,
                               chunk_tokens=chunk_tokens,
                               data_shards=shards, tracer=tracer,
                               **kw)

    mismatches: Dict[str, int] = {}
    chains_ok: Dict[str, bool] = {}
    heads_equal: Dict[str, bool] = {}

    def _leg(leg, ref, res):
        (sig_mm, mode_mm, ans_mm, mem_mm, hash_mm, audit_a,
         audit_b) = _compare_engine_runs(
            tasks, ref, res, member_names, workdir, f"obs-{leg}",
            (f"untraced-{leg}", f"traced-{leg}"))
        mismatches[leg] = (len(sig_mm) + len(mode_mm) + len(ans_mm)
                          + len(mem_mm) + len(hash_mm))
        chains_ok[leg] = bool(audit_a["ok"]) and bool(audit_b["ok"])
        heads_equal[leg] = audit_a["head"] == audit_b["head"]

    # leg 1: single-device, traced vs untraced (+ flushed span file)
    span_path = workdir / "spans-step.jsonl"
    base = _run()
    traced = _run(tracer=SpanTracer(span_path))
    _leg("step", base, traced)
    span_audit = verify_span_file(span_path)
    span_file_ok = (bool(span_audit["ok"])
                    and span_audit["head"] == traced.span_head)
    # determinism: same stream twice -> same span chain head
    traced2 = _run(tracer=SpanTracer())
    span_det = traced2.span_head == traced.span_head

    # leg 2: sharded, traced vs untraced
    if n_shards:
        base_sh = _run(shards=n_shards)
        traced_sh = _run(tracer=SpanTracer(), shards=n_shards)
        _leg(f"data{n_shards}", base_sh, traced_sh)

    # leg 3: traced crash -> traced recover vs untraced uninterrupted
    kill = max(1, base.step.ticks * 3 // 4)
    jp = workdir / "journal-obs.jsonl"
    try:
        _run(tracer=SpanTracer(), journal_path=jp,
             faults=FaultPlan.crash_at(kill))
    except SimulatedCrash:
        pass
    res_r = _run(tracer=SpanTracer(), recover=jp)
    _leg(f"recover@{kill}", base, res_r)
    restore_spans = sum(1 for s in res_r.spans
                       if s["phase"] == "restore")

    # lineage: walk + hash-verify every served task's answer
    lineage_failures: List[str] = []
    walked = 0
    for tid in sorted({t.task_id for t in tasks}):
        lin = lineage(traced.spans, tid)
        walked += 1
        if not lin["ok"]:
            lineage_failures.extend(
                f"{tid}: {f}" for f in lin["hash_failures"])

    # attribution: every escalated row vs the offline oracle, exact
    att_rows, att_mm = _attribution_oracle_check(
        tasks, traced, member_names)

    # wave engine: post-hoc spans ride the queued path
    eng_w = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=route_fn)
    res_w = eng_w.run_queued(tasks, policy, tracer=SpanTracer())

    return ObsReport(
        n_tasks=len(tasks), mismatches=mismatches,
        chains_ok=chains_ok, heads_equal=heads_equal,
        span_heads_deterministic=span_det,
        span_file_ok=span_file_ok,
        span_records=len(traced.spans),
        lineage_tasks=walked, lineage_failures=lineage_failures,
        attribution_rows=att_rows, attribution_mismatches=att_mm,
        crash_restored=res_r.restored_rows,
        crash_restore_spans=restore_spans,
        wave_spans=len(res_w.spans or []))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--duplicate-rate", type=float, default=0.15)
    ap.add_argument("--no-overlap", action="store_true")
    ap.add_argument("--engine-compaction", action="store_true",
                    help="also check compacted<->masked equivalence of "
                         "the real-model engine (16 tasks, tiny zoo)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="also check paged<->dense KV-cache equivalence"
                         " of the real-model engine over --tasks tasks")
    ap.add_argument("--paged-only", action="store_true",
                    help="run only the paged-KV check (implies "
                         "--paged-kv; the fast CI job's mode)")
    ap.add_argument("--step-loop", action="store_true",
                    help="also check wave-lockstep<->step-loop "
                         "equivalence of the real-model engine over "
                         "--tasks long-prompt tasks")
    ap.add_argument("--step-only", action="store_true",
                    help="run only the step-loop check (implies "
                         "--step-loop; the fast CI job's mode)")
    ap.add_argument("--sharded", action="store_true",
                    help="also check sharded<->single-device step-loop"
                         " equivalence (data=--shards mesh, per-shard "
                         "paged KV pools) over --tasks tasks")
    ap.add_argument("--sharded-only", action="store_true",
                    help="run only the sharded check (implies "
                         "--sharded; the fast CI job's mode)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--megastep", action="store_true",
                    help="also check megastep<->per-tick step-loop "
                         "equivalence (K in {1,4,16} fused decode "
                         "ticks, single-device and sharded legs) over "
                         "--tasks tasks")
    ap.add_argument("--megastep-only", action="store_true",
                    help="run only the megastep check (implies "
                         "--megastep; the fast CI job's mode)")
    ap.add_argument("--megastep-shards", type=int, default=4,
                    help="shard count for the sharded megastep legs "
                         "(0 disables them)")
    ap.add_argument("--crash", action="store_true",
                    help="also check kill->journal-recover equivalence"
                         " (single-device + data=--shards legs, "
                         "including a torn-journal-tail kill)")
    ap.add_argument("--crash-only", action="store_true",
                    help="run only the crash-recovery check (implies "
                         "--crash; the fast CI job's mode)")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="kill tick for every crash leg (implies "
                         "--crash; default -1 auto-picks the midpoint "
                         "and 3/4 of the uninterrupted run)")
    ap.add_argument("--faults", action="store_true",
                    help="also check the degraded-fleet legs: member "
                         "quarantine + shard loss under a seeded fault"
                         " plan, deterministic replay, hash-chained "
                         "fault trace")
    ap.add_argument("--faults-only", action="store_true",
                    help="run only the degraded-fleet check (implies "
                         "--faults; the fast CI job's mode)")
    ap.add_argument("--mesh2d", action="store_true",
                    help="also check 2-D (data, model) mesh <-> "
                         "single-device step-loop equivalence over a "
                         "mixed dense+MoE fleet (tensor-parallel "
                         "members, kv-head-sliced pages; includes "
                         "megastep, megastep-auto and crash-recovery "
                         "legs)")
    ap.add_argument("--mesh2d-only", action="store_true",
                    help="run only the 2-D mesh check (implies "
                         "--mesh2d; the fast CI job's mode)")
    ap.add_argument("--mesh-data", type=int, default=2,
                    help="data-axis size of the 2-D mesh check")
    ap.add_argument("--mesh-model", type=int, default=2,
                    help="model-axis size of the 2-D mesh check")
    ap.add_argument("--hetero", action="store_true",
                    help="also check heterogeneous-paged-state "
                         "equivalence (quant KV pages, recurrent-state"
                         " lanes, ring pages; stepped/wave/sharded/"
                         "crash legs vs the dense-cache baseline)")
    ap.add_argument("--hetero-only", action="store_true",
                    help="run only the heterogeneous-layout check "
                         "(implies --hetero; the fast CI job's mode)")
    ap.add_argument("--obs", action="store_true",
                    help="also check the observability layer: span-"
                         "traced runs bit-identical to untraced "
                         "(step, data=--shards, crash->recover legs),"
                         " deterministic + auditable span chain, PROV"
                         " lineage walk verifying every hash, and "
                         "on-capacity attribution matching the "
                         "offline leave-one-out oracle exactly")
    ap.add_argument("--obs-only", action="store_true",
                    help="run only the observability check (implies "
                         "--obs; the fast CI job's mode)")
    ap.add_argument("--chunk-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    only = (args.paged_only or args.step_only or args.sharded_only
            or args.megastep_only or args.crash_only
            or args.faults_only or args.mesh2d_only
            or args.hetero_only or args.obs_only)
    ok = True
    if not only:
        stream = generate_workload(WorkloadConfig(
            n_tasks=args.tasks, seed=args.seed,
            duplicate_rate=args.duplicate_rate))
        report, _, _ = run_equivalence(
            stream, acfg=ACARConfig(seed=args.seed),
            policy=MicroBatchPolicy(max_batch_size=args.batch_size),
            overlap=not args.no_overlap)
        print(report.summary())
        ok = report.ok
    if args.engine_compaction and not only:
        creport = run_engine_compaction_equivalence(
            seed=args.seed, batch_size=args.batch_size)
        print(creport.summary())
        ok = ok and creport.ok
    if (args.paged_kv or args.paged_only) and not args.step_only:
        preport = run_paged_kv_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            duplicate_rate=args.duplicate_rate)
        print(preport.summary())
        ok = ok and preport.ok
    if (args.step_loop or args.step_only) and not args.sharded_only:
        sreport = run_step_loop_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            duplicate_rate=args.duplicate_rate)
        print(sreport.summary())
        ok = ok and sreport.ok
    if args.sharded or args.sharded_only:
        shreport = run_sharded_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            n_shards=args.shards,
            duplicate_rate=args.duplicate_rate)
        print(shreport.summary())
        ok = ok and shreport.ok
    if args.megastep or args.megastep_only:
        mreport = run_megastep_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            n_shards=args.megastep_shards or None,
            duplicate_rate=args.duplicate_rate)
        print(mreport.summary())
        ok = ok and mreport.ok
    if args.crash or args.crash_only or args.crash_at >= 0:
        crreport = run_crash_recovery_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            crash_at=args.crash_at if args.crash_at >= 0 else None,
            n_shards=args.shards or None,
            duplicate_rate=args.duplicate_rate)
        print(crreport.summary())
        ok = ok and crreport.ok
    if args.mesh2d or args.mesh2d_only:
        m2report = run_mesh2d_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            data_shards=args.mesh_data,
            model_shards=args.mesh_model,
            duplicate_rate=args.duplicate_rate)
        print(m2report.summary())
        ok = ok and m2report.ok
    if args.hetero or args.hetero_only:
        hreport = run_hetero_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            n_shards=args.shards,
            duplicate_rate=args.duplicate_rate)
        print(hreport.summary())
        ok = ok and hreport.ok
    if args.faults or args.faults_only:
        freport = run_degraded_fleet(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            n_shards=args.shards,
            duplicate_rate=args.duplicate_rate)
        print(freport.summary())
        ok = ok and freport.ok
    if args.obs or args.obs_only:
        oreport = run_obs_equivalence(
            n_tasks=args.tasks, seed=args.seed,
            batch_size=args.batch_size,
            chunk_tokens=args.chunk_tokens,
            n_shards=args.shards or None,
            duplicate_rate=args.duplicate_rate)
        print(oreport.summary())
        ok = ok and oreport.ok
    return 0 if ok else 1


def _maybe_reexec_for_sharding() -> None:
    """The sharded check needs a multi-device mesh, and jax locks the
    host device count at first backend init — so when ``--sharded`` is
    requested without enough forced host devices, re-exec this script
    with XLA_FLAGS merged (never clobbered: an existing user-set count
    wins, and the mesh constructor raises a clear error if it is too
    small)."""
    import sys

    from repro.xla_flags import argv_int, reexec_with_host_devices
    argv = sys.argv[1:]
    if not ({"--sharded", "--sharded-only", "--megastep",
             "--megastep-only", "--crash", "--crash-only",
             "--crash-at", "--faults", "--faults-only",
             "--mesh2d", "--mesh2d-only", "--hetero",
             "--hetero-only", "--obs", "--obs-only"} & set(argv)):
        return
    # the 2-D check needs data*model devices; force 8 so the default
    # (2, 2) mesh and any reasonable override both fit
    mesh2d = bool({"--mesh2d", "--mesh2d-only"} & set(argv))
    reexec_with_host_devices(
        max(argv_int(argv, "--shards", 4),
            argv_int(argv, "--megastep-shards", 4),
            8 if mesh2d else 1,
            argv_int(argv, "--mesh-data", 2)
            * argv_int(argv, "--mesh-model", 2) if mesh2d else 1,
            1),
        [__file__] + argv)


if __name__ == "__main__":
    _maybe_reexec_for_sharding()
    raise SystemExit(main())
