"""Deterministic simulation harness for the ACAR serving scheduler.

Two pieces:

* a **seeded synthetic-workload generator** — draws task streams from
  the calibrated paper suite (optionally with duplicate resubmissions,
  which exercise the scheduler's probe cache), fully reproducible from
  a seed;
* an **equivalence checker** — drives the same workload through the
  sequential ``ACAROrchestrator`` and the ``ContinuousBatchingScheduler``
  and checks, per task: identical routing mode, identical final answer,
  identical trace record hash — and globally: both artifact hash
  chains verify, the chain heads are byte-identical (batching may not
  perturb the audit trail), and the scheduler's ``logical_time`` is the
  total order of admission.

Run standalone:

    PYTHONPATH=src:tests python tests/harness/simulate.py \
        --tasks 200 --seed 0 --batch-size 8
"""
from __future__ import annotations

import argparse
import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.acar import ACARConfig
from repro.core.backends import GenResult, paper_backends
from repro.core.orchestrator import ACAROrchestrator, TaskOutcome
from repro.data.tasks import Task, paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.teamllm.artifacts import ArtifactStore


# ----------------------------------------------------------------------
# scripted backend: exact control over probe/ensemble answers, for
# sigma edge-case tests
# ----------------------------------------------------------------------
@dataclass
class ScriptedBackend:
    """Deterministic backend returning scripted answers.

    ``script`` maps (task_id, sample_idx) -> semantic answer; missing
    keys fall back to ``default``. Pure function of its inputs, so it
    is safe to share between the sequential and batched paths.
    """
    name: str
    script: Dict[Tuple[str, int], str] = field(default_factory=dict)
    default: str = "a"
    cost: float = 0.001
    latency_ms: float = 100.0

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int = 0, seed: int = 0,
                 **_kw) -> GenResult:
        ans = self.script.get((task.task_id, sample_idx), self.default)
        return GenResult(response=f"answer: {ans}",
                         semantic_answer=ans, cost=self.cost,
                         latency_ms=self.latency_ms, score=0.0)


def scripted_task(task_id: str = "t0", gold: str = "a") -> Task:
    return Task(task_id=task_id, benchmark="scripted",
                kind="reasoning", text=f"scripted task {task_id}",
                gold=gold, difficulty=0.0)


# ----------------------------------------------------------------------
# workload generation
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class WorkloadConfig:
    n_tasks: int = 200
    seed: int = 0
    # probability a request resubmits an earlier task (probe-cache
    # traffic); 0 disables duplicates
    duplicate_rate: float = 0.15


def generate_workload(cfg: WorkloadConfig) -> List[Task]:
    """Seeded synthetic request stream over the calibrated paper mix."""
    pool = paper_suite(seed=cfg.seed)
    rng = np.random.default_rng(cfg.seed + 0x5EED)
    stream: List[Task] = []
    for _ in range(cfg.n_tasks):
        if stream and rng.random() < cfg.duplicate_rate:
            stream.append(stream[int(rng.integers(len(stream)))])
        else:
            stream.append(pool[int(rng.integers(len(pool)))])
    return stream


# ----------------------------------------------------------------------
# equivalence checking
# ----------------------------------------------------------------------
@dataclass
class EquivalenceReport:
    n_tasks: int
    mode_mismatches: List[str]
    answer_mismatches: List[str]
    hash_mismatches: List[str]
    sequential_chain_ok: bool
    scheduler_chain_ok: bool
    chain_heads_equal: bool
    logical_time_ok: bool
    probe_cache_hits: int
    speedup_vs_sequential: float

    @property
    def ok(self) -> bool:
        return (not self.mode_mismatches
                and not self.answer_mismatches
                and not self.hash_mismatches
                and self.sequential_chain_ok
                and self.scheduler_chain_ok
                and self.chain_heads_equal
                and self.logical_time_ok)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} "
                f"mode_mismatches={len(self.mode_mismatches)} "
                f"answer_mismatches={len(self.answer_mismatches)} "
                f"hash_mismatches={len(self.hash_mismatches)} "
                f"chains_ok={self.sequential_chain_ok and self.scheduler_chain_ok} "
                f"heads_equal={self.chain_heads_equal} "
                f"logical_time_ok={self.logical_time_ok} "
                f"cache_hits={self.probe_cache_hits} "
                f"speedup={self.speedup_vs_sequential:.2f}x "
                f"=> {'EQUIVALENT' if self.ok else 'DIVERGENT'}")


def run_equivalence(tasks: Sequence[Task],
                    acfg: ACARConfig = ACARConfig(),
                    policy: MicroBatchPolicy = MicroBatchPolicy(),
                    workdir: Optional[Path] = None,
                    run_id: str = "sim",
                    overlap: bool = True,
                    backends_factory=paper_backends,
                    probe_name: str = "gemini-2.0-flash"
                    ) -> Tuple[EquivalenceReport,
                               List[TaskOutcome], List[TaskOutcome]]:
    """Drive ``tasks`` through both execution paths and compare."""
    if workdir is None:
        workdir = Path(tempfile.mkdtemp(prefix="acar-sim-"))
    workdir = Path(workdir)

    seq_backs = backends_factory()
    seq_store = ArtifactStore(workdir / "sequential.jsonl")
    seq = ACAROrchestrator(acfg, seq_backs[probe_name], seq_backs,
                           store=seq_store, run_id=run_id
                           ).run_suite(tasks)

    sched_backs = backends_factory()
    sched_store = ArtifactStore(workdir / "scheduler.jsonl")
    sched = ContinuousBatchingScheduler(
        acfg, sched_backs[probe_name], sched_backs, store=sched_store,
        run_id=run_id, policy=policy, overlap=overlap)
    bat = sched.serve(tasks)

    mode_mm, ans_mm, hash_mm = [], [], []
    for a, b in zip(seq, bat):
        tid = a.trace.task_id
        if a.trace.mode != b.trace.mode:
            mode_mm.append(
                f"{tid}: {a.trace.mode} != {b.trace.mode}")
        if a.trace.final_answer != b.trace.final_answer:
            ans_mm.append(
                f"{tid}: {a.trace.final_answer!r} != "
                f"{b.trace.final_answer!r}")
        if a.trace.record_hash() != b.trace.record_hash():
            hash_mm.append(tid)

    seq_audit = ArtifactStore(workdir / "sequential.jsonl").audit()
    sched_audit = ArtifactStore(workdir / "scheduler.jsonl").audit()
    lt = [o.trace.logical_time for o in bat]
    admitted = [o.trace.schedule["admitted"] for o in bat]
    logical_time_ok = lt == list(range(len(bat))) and lt == admitted

    report = EquivalenceReport(
        n_tasks=len(tasks),
        mode_mismatches=mode_mm,
        answer_mismatches=ans_mm,
        hash_mismatches=hash_mm,
        sequential_chain_ok=bool(seq_audit["ok"]),
        scheduler_chain_ok=bool(sched_audit["ok"]),
        chain_heads_equal=seq_audit["head"] == sched_audit["head"],
        logical_time_ok=logical_time_ok,
        probe_cache_hits=sched.cache.hits,
        speedup_vs_sequential=sched.stats.speedup_vs_sequential,
    )
    return report, seq, bat


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--duplicate-rate", type=float, default=0.15)
    ap.add_argument("--no-overlap", action="store_true")
    args = ap.parse_args(argv)

    stream = generate_workload(WorkloadConfig(
        n_tasks=args.tasks, seed=args.seed,
        duplicate_rate=args.duplicate_rate))
    report, _, _ = run_equivalence(
        stream, acfg=ACARConfig(seed=args.seed),
        policy=MicroBatchPolicy(max_batch_size=args.batch_size),
        overlap=not args.no_overlap)
    print(report.summary())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
