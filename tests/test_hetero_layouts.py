"""Heterogeneous page layouts (property tests).

Three layout-specific contracts the stepped engine rests on:

* **ring pages** — a sliding-window member's window-capped ring pages
  must emit logits bit-identical to the dense SWA reference (the
  ``ring_compress``'d contiguous cache) at every prompt length, in
  particular every offset where the ring's write pointer straddles a
  page boundary or wraps;
* **recurrent-state lanes** — lane alloc/fork/retire over an SSM
  member's O(1) state must leak nothing: forked lanes are private
  (refcount 1, pairwise distinct), and full retirement returns the
  pool to its scratch-only footprint;
* **quant pages** — int8 code + scale-plane pages round-trip
  bit-for-bit against the dense quant cache: same codes, same scales,
  same logits at prefill and at every decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                    # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.configs.registry import get_config
from repro.models import params as params_lib
from repro.models import transformer as T
from repro.serving.kv_pool import PagedKVServer, pages_for

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow

PAGE = 4
WINDOW = 8
MAX_NEW = 3

# property bodies cannot take pytest fixtures (propshim generates
# zero-arg wrappers), so models build lazily into a module cache
_MODELS = {}


def _model(kind):
    if kind not in _MODELS:
        if kind == "mamba":
            cfg = get_config("falcon-mamba-7b", reduced=True).replace(
                dtype="float32")
        else:
            cfg = get_config("smollm-135m", reduced=True).replace(
                dtype="float32", tie_embeddings=True)
            if kind == "ring":
                cfg = cfg.replace(window=WINDOW)
            elif kind == "quant":
                cfg = cfg.replace(kv_quant=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(7))
        _MODELS[kind] = (cfg, prm)
    return _MODELS[kind]


def _paged_row(cfg, s, m):
    """A server plus one row's full-width (prefill+decode) block
    table, allocated exactly as the step loop would."""
    srv = PagedKVServer(cfg, page_size=PAGE, prefix_cache_entries=0)
    srv.ensure_capacity_stream(2, s, 1, m)
    g = srv.row_geometry(s, m)
    table = np.asarray(srv._alloc_retry(g.nb), np.int32)
    return srv, g, table


# ----------------------------------------------------------------------
# ring pages vs dense sliding-window reference
# ----------------------------------------------------------------------
@settings(max_examples=14, deadline=None)
@given(st.integers(min_value=WINDOW - 2,
                   max_value=WINDOW + 2 * PAGE + 1))
def test_ring_wraparound_bit_equals_dense_swa(s):
    """Sweep prompt lengths across the window edge: every page-offset
    phase (s mod page), prompts shorter than the ring, exactly the
    ring, and long enough that prefill itself wraps — the paged ring
    must match the dense SWA cache bit-for-bit through prefill and
    every decode step."""
    cfg, prm = _model("ring")
    m = MAX_NEW
    ids = jax.random.randint(jax.random.PRNGKey(100 + s), (1, s), 0,
                             cfg.vocab_size)
    lg_d, cache = T.prefill(cfg, prm, ids, cache_len=s + m)

    srv, g, table = _paged_row(cfg, s, m)
    # the ring caps the row's pages at ceil(window/page), regardless
    # of prompt length
    assert g.nb == g.nbp == pages_for(min(s + m, WINDOW), PAGE)
    lg_p, pages = T.prefill_paged(cfg, prm, ids, srv.pages,
                                  jnp.asarray(table[None, :g.nbp]),
                                  cache_len=s + m)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))

    tok = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)
    bt = jnp.asarray(table[None])
    for i in range(m - 1):
        pos = jnp.int32(s + i)
        lg_d, cache = T.decode_step(cfg, prm, cache, tok, pos)
        lg_p, pages = T.decode_step_paged(cfg, prm, pages, bt, tok,
                                          pos, cache_len=s + m)
        np.testing.assert_array_equal(np.asarray(lg_d),
                                      np.asarray(lg_p))
        tok = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)


# ----------------------------------------------------------------------
# recurrent-state lanes: fork/retire accounting
# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=40))
def test_lane_fork_retire_leaks_no_lanes(rows, n_samples, prompt_len):
    """Rows of SSM state: one prefill lane each, forked across
    n_samples probe lanes. Lane geometry is O(1) in prompt length,
    forked lanes are private (no sharing — the whole state is
    writable), and retiring everything returns the pool to its
    scratch-only footprint."""
    cfg, _ = _model("mamba")
    srv = PagedKVServer(cfg, page_size=PAGE, prefix_cache_entries=0)
    srv.ensure_capacity_stream(rows, prompt_len, n_samples, MAX_NEW)
    g = srv.row_geometry(prompt_len, MAX_NEW)
    assert (g.n_shared, g.tail_tokens, g.nbp, g.nb, g.n_tail) \
        == (0, 0, 1, 1, 1)
    base = srv.pool.pages_in_use
    assert base == srv._scratch.size

    held = []
    for _ in range(rows):
        snap = srv._alloc_retry(g.nbp)            # prefill lane
        forks = srv._alloc_retry(n_samples * g.n_tail)
        ids = np.concatenate([snap, forks])
        # every lane private: pairwise distinct, refcount exactly 1
        assert len(set(ids.tolist())) == ids.size
        for p in ids:
            assert srv.pool.refcount(int(p)) == 1
        held.append((snap, forks))
    assert srv.pool.pages_in_use == base + rows * (1 + n_samples)

    for snap, forks in held:
        srv.pool.release(forks)
        srv.pool.release(snap)
    assert srv.pool.pages_in_use == srv._scratch.size
    assert srv.pool.highwater <= srv.pool.num_pages


def test_lane_fork_copies_state_not_aliases():
    """fork_pages on the lane pytree copies the source row's conv+SSM
    state into the destination lane; mutating the fork afterwards must
    not write through to the source."""
    from repro.sampling import fork_pages
    cfg, _ = _model("mamba")
    srv = PagedKVServer(cfg, page_size=PAGE, prefix_cache_entries=0)
    srv.ensure_capacity_stream(2, 8, 2, MAX_NEW)
    pages = jax.tree.map(
        lambda a: jax.random.normal(
            jax.random.PRNGKey(a.ndim), a.shape).astype(a.dtype),
        srv.pages)
    src, dst = jnp.asarray([0]), jnp.asarray([1])
    forked = fork_pages(pages, src, dst)
    for leaf_name in ("conv", "h"):
        np.testing.assert_array_equal(
            np.asarray(forked[leaf_name][:, 1]),
            np.asarray(forked[leaf_name][:, 0]))
    poked = jax.tree.map(lambda a: a.at[:, 1].add(1.0), forked)
    for leaf_name in ("conv", "h"):
        np.testing.assert_array_equal(
            np.asarray(poked[leaf_name][:, 0]),
            np.asarray(forked[leaf_name][:, 0]))


# ----------------------------------------------------------------------
# quant pages vs the dense quant cache
# ----------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=3, max_value=3 * PAGE + 2))
def test_quant_pages_roundtrip_bit_equals_dense_quant(s):
    """int8 code pages and their f32 scale planes hold exactly the
    bytes the dense quant cache holds (same quantize_kv, page-packed),
    and prefill + decode logits match the dense quant path
    bit-for-bit."""
    cfg, prm = _model("quant")
    m = MAX_NEW
    ids = jax.random.randint(jax.random.PRNGKey(200 + s), (1, s), 0,
                             cfg.vocab_size)
    lg_d, cache = T.prefill(cfg, prm, ids, cache_len=s + m)
    assert cache["layers"]["k"].dtype == jnp.int8

    srv, g, table = _paged_row(cfg, s, m)
    lg_p, pages = T.prefill_paged(cfg, prm, ids, srv.pages,
                                  jnp.asarray(table[None, :g.nbp]))
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
    assert pages["k"].dtype == jnp.int8
    assert pages["k_scale"].dtype == jnp.float32

    def _gathered(leaf):
        """Row view of the paged bytes over the prompt prefix."""
        flat = leaf[:, table[:g.nbp]].reshape(
            (leaf.shape[0], g.nbp * PAGE) + leaf.shape[3:])
        return np.asarray(flat[:, :s])

    for name in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            _gathered(pages[name]),
            np.asarray(cache["layers"][name][:, 0, :s]), name)

    tok = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)
    bt = jnp.asarray(table[None])
    for i in range(m - 1):
        pos = jnp.int32(s + i)
        lg_d, cache = T.decode_step(cfg, prm, cache, tok, pos)
        lg_p, pages = T.decode_step_paged(cfg, prm, pages, bt, tok,
                                          pos, cache_len=s + m)
        np.testing.assert_array_equal(np.asarray(lg_d),
                                      np.asarray(lg_p))
        # the decode write itself round-trips: codes + scales at pos
        # match the dense cache's slot
        pg, off = int(pos) // PAGE, int(pos) % PAGE
        for name in ("k", "v", "k_scale", "v_scale"):
            np.testing.assert_array_equal(
                np.asarray(pages[name][:, table[pg], off]),
                np.asarray(cache["layers"][name][:, 0, int(pos)]),
                name)
        tok = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)


def test_quant_prefix_cache_hit_roundtrips_bitwise():
    """The cross-request prefix LRU serves quant layouts (int8 codes +
    scale planes are position-independent, so retained pages are
    directly reusable): a second probe wave over an identical prompt
    hits the cache instead of re-prefilling, the retained code/scale
    pages hold exactly the dense quant cache's bytes before and after
    the hit (COW keeps them immutable), and the hit wave decodes to
    the same tokens as the miss wave."""
    cfg, prm = _model("quant")
    s, m, n = 9, MAX_NEW, 2
    ids = np.asarray(jax.random.randint(
        jax.random.PRNGKey(300), (1, s), 0, cfg.vocab_size), np.int32)
    _, cache = T.prefill(cfg, prm, jnp.asarray(ids), cache_len=s + m)

    srv = PagedKVServer(cfg, page_size=PAGE, prefix_cache_entries=4)
    key = jax.random.PRNGKey(13)
    out1, h1 = srv.probe_wave(prm, ids, n, max_new_tokens=m,
                              temperature=0.0, key=key,
                              eos_id=-1, pad_id=0)
    h1.close()
    assert srv.stats.prefill_tokens_reused_prefix == 0
    entry = srv._prefix_lookup(ids[0].tobytes())
    assert entry is not None

    row_pages = list(entry.shared) + (
        [entry.tail] if entry.tail is not None else [])

    def _gathered(name):
        leaf = srv.pages[name]
        flat = np.asarray(leaf[:, np.asarray(row_pages)])
        flat = flat.reshape((leaf.shape[0], len(row_pages) * PAGE)
                            + flat.shape[3:])
        return flat[:, :s]

    names = ("k", "v", "k_scale", "v_scale")
    for name in names:
        np.testing.assert_array_equal(
            _gathered(name),
            np.asarray(cache["layers"][name][:, 0, :s]), name)
    snap = {name: _gathered(name).copy() for name in names}

    computed = srv.stats.prefill_tokens_computed
    out2, h2 = srv.probe_wave(prm, ids, n, max_new_tokens=m,
                              temperature=0.0, key=key,
                              eos_id=-1, pad_id=0)
    h2.close()
    assert srv.stats.prefill_tokens_computed == computed
    assert srv.stats.prefill_tokens_reused_prefix == s
    np.testing.assert_array_equal(out1.tokens, out2.tokens)
    np.testing.assert_array_equal(out1.logprobs, out2.logprobs)
    for name in names:
        np.testing.assert_array_equal(_gathered(name), snap[name],
                                      name)
