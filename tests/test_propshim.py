"""The hypothesis fallback shim itself: seeded, reproducible, and
settings-aware in either decorator order."""
from _propshim import given, settings
from _propshim import strategies as st


def test_settings_above_given():
    calls = []

    @settings(max_examples=7)
    @given(st.integers(0, 5))
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == 7
    assert all(0 <= x <= 5 for x in calls)


def test_settings_beneath_given():
    calls = []

    @given(st.integers(0, 5))
    @settings(max_examples=9)
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == 9


def test_examples_are_deterministic():
    runs = []
    for _ in range(2):
        calls = []

        @given(st.lists(st.sampled_from("abc"), min_size=1, max_size=4))
        def prop(xs):
            calls.append(tuple(xs))

        prop()
        runs.append(calls)
    assert runs[0] == runs[1]


def test_wrapper_hides_generated_params_from_pytest():
    @given(st.integers(0, 1))
    def prop(x):
        pass

    import inspect
    assert inspect.signature(prop).parameters == {}
