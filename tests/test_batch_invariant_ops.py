"""Bitwise batch-invariance of the blocked MLP/MoE math.

XLA's CPU backend picks dot tilings *per shape*: a token's projection
bits can depend on how many other tokens share the GEMM and on how many
output columns the local tensor-parallel shard computes. The serving
engine's compaction contract ("a row's bits never change when the rows
around it do") and the 2-D mesh's bit-equivalence contract ("model=m
column-parallel execution is bit-identical to single-device") both die
if that leaks into the model math.

``models.blocking`` fixes both by running every row-parallel projection
over fixed-shape (TOKEN_BLOCK, d) row blocks: one static shape -> one
kernel -> one reduction order. These tests pin the two properties the
scheme rests on, at the exact shapes the serving configs use:

* fixed-shape invariance: at the block shape, an output row's bits
  depend only on its own input row (zero-padding and neighbour content
  are invisible), so blocked composition over any batch split is exact;
* column-split exactness: at the block shape, a projection computed as
  the concatenation of column slices (the tensor-parallel layout, with
  its all-gather-then-contract epilogue) is bit-identical to the full
  projection, for every projection width the serving configs produce.

Plus the ref-oracle contract for the MoE expert FFN: the gather path's
``_expert_swiglu`` routes through ``ops.fused_swiglu``, which must be
bit-identical to ``kernels.ref.fused_swiglu_ref`` off-TPU and allclose
to the plain unblocked einsum math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                    # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.configs.base import MoEConfig
from repro.configs.registry import get_config
from repro.kernels import ref
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.blocking import TOKEN_BLOCK, blocked_rows
from repro.models.layers import swiglu_mlp

D = 192          # serving configs' d_model (smollm reduced)


def _bits_equal(a, b):
    return np.array_equal(np.asarray(a).view(np.uint8),
                          np.asarray(b).view(np.uint8))


def _rng_mats(seed, *shapes):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(s).astype(np.float32))
            for s in shapes]


# ----------------------------------------------------------------------
# blocked_rows mechanics
# ----------------------------------------------------------------------
@pytest.mark.parametrize("t", [1, 7, 8, 9, 16, 23])
def test_blocked_rows_restores_shape_and_tail(t):
    x, w = _rng_mats(0, (t, D), (D, 64))
    y = blocked_rows(lambda xb: jnp.einsum("td,df->tf", xb, w), x)
    assert y.shape == (t, 64)
    assert bool(jnp.isfinite(y).all())


def test_blocked_rows_zero_pad_invisible():
    """A short tail block's rows must not see the zero padding: the
    same rows embedded in a full block of other (non-zero) rows come
    out bit-identical."""
    x, filler, w = _rng_mats(1, (3, D), (5, D), (D, 512))
    fn = lambda xb: jnp.einsum("td,df->tf", xb, w)
    short = blocked_rows(fn, x)                       # padded with zeros
    full = blocked_rows(fn, jnp.concatenate([x, filler]))[:3]
    assert _bits_equal(short, full)


# ----------------------------------------------------------------------
# column-split exactness at the fixed block shape
# ----------------------------------------------------------------------
@pytest.mark.parametrize("f", [512, 256, 64, 32])
@pytest.mark.parametrize("m", [2, 4])
def test_colsplit_exact_at_block_shape(f, m):
    """(TOKEN_BLOCK, D) x (D, f) as m concatenated column slices ==
    the full projection, bit for bit. These are exactly the per-shard
    GEMMs the column-parallel tensor layout runs (f covers d_ff,
    d_ff_expert, q-proj and kv-proj widths of the serving configs)."""
    if f % m:
        pytest.skip("width not divisible")
    x, w = _rng_mats(f * 31 + m, (TOKEN_BLOCK, D), (D, f))
    full = jnp.einsum("td,df->tf", x, w)
    fl = f // m
    parts = [jnp.einsum("td,df->tf", x, w[:, j * fl:(j + 1) * fl])
             for j in range(m)]
    assert _bits_equal(full, jnp.concatenate(parts, axis=1))


def test_swiglu_tp_simulation_bitwise():
    """End-to-end: swiglu_mlp computed the way a model=2 shard pair
    does (local column slices of w_gate/w_up, concat standing in for
    the tiled all-gather, full-length down-projection) is bit-identical
    to the unsharded path."""
    x, wg, wu, wd = _rng_mats(7, (13, D), (D, 512), (D, 512), (512, D))
    params = {"w_gate": wg, "w_up": wu, "w_down": wd}
    want = swiglu_mlp(params, x)

    def shard_blk(xb):
        hs = []
        for j in range(2):
            sl = slice(j * 256, (j + 1) * 256)
            g = jnp.einsum("td,df->tf", xb, wg[:, sl])
            u = jnp.einsum("td,df->tf", xb, wu[:, sl])
            hs.append(jax.nn.silu(g.astype(jnp.float32)
                                  ).astype(xb.dtype) * u)
        h = jnp.concatenate(hs, axis=-1)
        return jnp.einsum("tf,fd->td", h, wd)

    got = blocked_rows(shard_blk, x)
    assert _bits_equal(want, got)


# ----------------------------------------------------------------------
# batch-composition / permutation invariance (property)
# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.lists(st.integers(1, 39), max_size=3),
       st.integers(0, 2 ** 31 - 1))
def test_swiglu_batch_composition_invariant(t, cuts, seed):
    """Splitting a token batch at arbitrary points and running each
    piece separately reproduces the full run bit for bit — block
    membership shifts, the bits must not."""
    x, wg, wu, wd = _rng_mats(seed, (t, D), (D, 512), (D, 512), (512, D))
    params = {"w_gate": wg, "w_up": wu, "w_down": wd}
    full = swiglu_mlp(params, x)
    bounds = sorted({c % t for c in cuts} | {0, t})
    pieces = [swiglu_mlp(params, x[a:b])
              for a, b in zip(bounds, bounds[1:])]
    assert _bits_equal(full, jnp.concatenate(pieces))


def _gather_moe_setup(seed, t):
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                     impl="gather", first_moe_layer=0)
    cfg = get_config("smollm-135m", reduced=True).replace(
        dtype="float32", moe=mcfg)
    x, router, wg, wu, wd = _rng_mats(
        seed, (t, D), (D, 4), (4, D, 256), (4, D, 256), (4, 256, D))
    p = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
    return cfg, p, x


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 24), st.lists(st.integers(1, 23), max_size=2),
       st.integers(0, 2 ** 31 - 1))
def test_moe_gather_batch_composition_invariant(t, cuts, seed):
    """Capacity-free gather MoE: a token's output bits are independent
    of which other tokens share the batch (the property that lifts the
    MoE exclusion from compacted serving)."""
    cfg, p, x = _gather_moe_setup(seed, t)
    full, _ = moe_mod.moe_ffn_gather(cfg, p, x[None])
    bounds = sorted({c % t for c in cuts} | {0, t})
    pieces = [moe_mod.moe_ffn_gather(cfg, p, x[a:b][None])[0][0]
              for a, b in zip(bounds, bounds[1:])]
    assert _bits_equal(full[0], jnp.concatenate(pieces))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 24), st.integers(0, 2 ** 31 - 1))
def test_moe_gather_permutation_invariant(t, seed):
    cfg, p, x = _gather_moe_setup(seed, t)
    perm = np.random.default_rng(seed ^ 0x5bd1e995).permutation(t)
    y, _ = moe_mod.moe_ffn_gather(cfg, p, x[None])
    yp, _ = moe_mod.moe_ffn_gather(cfg, p, x[perm][None])
    assert _bits_equal(y[0][perm], yp[0])


def test_moe_gather_decode_matches_isolated_rows():
    """The decode path (``mlp_apply_token`` -> gather MoE) is the same
    bit-contract: a token decoded inside a batch of 7 equals the same
    token decoded alone."""
    cfg, p, x = _gather_moe_setup(11, 7)
    batch = T.mlp_apply_token(cfg, p, x)
    solo = jnp.concatenate(
        [T.mlp_apply_token(cfg, p, x[i:i + 1]) for i in range(7)])
    assert _bits_equal(batch, solo)


# ----------------------------------------------------------------------
# MoE expert FFN <-> fused-SwiGLU ref oracle (kernel routing contract)
# ----------------------------------------------------------------------
def test_expert_swiglu_matches_fused_swiglu_ref():
    """Off-TPU the gather path's expert FFN must route through
    ``ops.fused_swiglu``'s jnp oracle: blocked ``fused_swiglu_ref``
    bit-identical, plain unblocked einsum math allclose."""
    xt, wg, wu, wd = _rng_mats(3, (19, D), (D, 256), (D, 256), (256, D))
    got = moe_mod._expert_swiglu(xt, wg, wu, wd)
    oracle = blocked_rows(
        lambda xb: ref.fused_swiglu_ref(xb, wg, wu, wd), xt)
    assert _bits_equal(got, oracle)
    plain = ref.fused_swiglu_ref(xt, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain),
                               rtol=1e-5, atol=1e-5)


def test_gather_moe_routes_experts_through_fused_swiglu(monkeypatch):
    """The expert FFN actually goes through ``ops.fused_swiglu`` (the
    Pallas kernel on TPU): count calls."""
    from repro.kernels import ops
    calls = []
    real = ops.fused_swiglu

    def spy(x, wg, wu, wd, **kw):
        calls.append(x.shape)
        return real(x, wg, wu, wd, **kw)

    monkeypatch.setattr(ops, "fused_swiglu", spy)
    cfg, p, x = _gather_moe_setup(5, 6)
    with jax.disable_jit():
        moe_mod.moe_ffn_gather(cfg, p, x[None])
    assert calls, "expert FFN did not route through ops.fused_swiglu"
    assert all(s == (TOKEN_BLOCK, D) for s in calls), calls
