"""Unit + property tests for sigma (Def. 1) and routing (Def. 2)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                          # seeded fallback shim
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.core.routing import (
    ARENA_LITE, FULL_ARENA, SINGLE_AGENT, decide, execution_mode,
    majority_vote, models_for_mode)
from repro.core.sigma import (
    majority_vote_batch, route_batch, sigma, sigma_batch)

ENSEMBLE = ("m1", "m2", "m3")


# ----------------------------------------------------------------------
# host-side sigma
# ----------------------------------------------------------------------
def test_sigma_values_paper():
    assert sigma(["a", "a", "a"]) == 0.0
    assert sigma(["a", "a", "b"]) == 0.5
    assert sigma(["a", "b", "c"]) == 1.0


def test_sigma_order_invariant():
    assert sigma(["b", "a", "a"]) == sigma(["a", "a", "b"]) == 0.5


@given(st.lists(st.sampled_from("abcde"), min_size=2, max_size=7))
def test_sigma_matches_definition(answers):
    n = len(answers)
    expected = (len(set(answers)) - 1) / (n - 1)
    assert sigma(answers) == pytest.approx(expected)


@given(st.lists(st.sampled_from("abc"), min_size=3, max_size=3))
def test_sigma_discrete_for_n3(answers):
    assert sigma(answers) in (0.0, 0.5, 1.0)


# ----------------------------------------------------------------------
# routing (Def. 2 / Alg. 1)
# ----------------------------------------------------------------------
def test_execution_mode_mapping():
    assert execution_mode(0.0) == SINGLE_AGENT
    assert execution_mode(0.5) == ARENA_LITE
    assert execution_mode(1.0) == FULL_ARENA


def test_models_for_mode():
    assert models_for_mode(SINGLE_AGENT, ENSEMBLE) == []
    assert models_for_mode(ARENA_LITE, ENSEMBLE) == ["m1", "m2"]
    assert models_for_mode(FULL_ARENA, ENSEMBLE) == list(ENSEMBLE)


def test_decide_saves_calls():
    d0 = decide(0.0, ["a", "a", "a"], ENSEMBLE)
    d1 = decide(0.5, ["a", "a", "b"], ENSEMBLE)
    d2 = decide(1.0, ["a", "b", "c"], ENSEMBLE)
    assert (d0.ensemble_calls_saved, d1.ensemble_calls_saved,
            d2.ensemble_calls_saved) == (3, 1, 0)
    assert d0.probe_answer == "a"
    assert d1.probe_answer == "a"     # majority


@given(st.lists(st.sampled_from("abcd"), min_size=3, max_size=3))
def test_majority_vote_is_modal(answers):
    win = majority_vote(answers)
    counts = {a: answers.count(a) for a in answers}
    assert counts[win] == max(counts.values())


def test_majority_vote_tie_breaks_first():
    assert majority_vote(["x", "y", "z"]) == "x"


# ----------------------------------------------------------------------
# vectorised (on-device) versions agree with host versions
# ----------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.lists(st.integers(0, 4), min_size=3, max_size=3),
    min_size=1, max_size=16))
def test_sigma_batch_matches_host(rows):
    ids = jnp.asarray(np.array(rows, np.int32))
    got = np.asarray(sigma_batch(ids))
    want = [sigma([str(a) for a in row]) for row in rows]
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.lists(st.integers(0, 4), min_size=3, max_size=3),
    min_size=1, max_size=16))
def test_route_batch_matches_host(rows):
    ids = jnp.asarray(np.array(rows, np.int32))
    modes = np.asarray(route_batch(sigma_batch(ids)))
    for row, m in zip(rows, modes):
        want = {SINGLE_AGENT: 0, ARENA_LITE: 1, FULL_ARENA: 2}[
            execution_mode(sigma([str(a) for a in row]))]
        assert m == want


@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.lists(st.integers(0, 4), min_size=3, max_size=3),
    min_size=1, max_size=16))
def test_majority_vote_batch_matches_host(rows):
    ids = jnp.asarray(np.array(rows, np.int32))
    got = np.asarray(majority_vote_batch(ids))
    for row, g in zip(rows, got):
        assert str(g) == majority_vote([str(a) for a in row])
