"""Integration: the paper's headline claims hold on the full 1,510-task
suite through the real orchestrator + substrate (validates the
EXPERIMENTS.md reproduction, not just unit behaviour)."""
import numpy as np
import pytest

from benchmarks.common import run_all_configs


@pytest.fixture(scope="module")
def runs(tmp_path_factory):
    art = tmp_path_factory.mktemp("artifacts")
    return run_all_configs(seed=0, art_dir=art)


def test_ordering_single_arena2_acar_arena3(runs):
    """Paper Table 1 ordering: single < arena2 < acar_u < arena3."""
    assert runs["single_model"].accuracy < runs["arena_2"].accuracy
    assert runs["arena_2"].accuracy < runs["acar_u"].accuracy
    assert runs["acar_u"].accuracy < runs["arena_3"].accuracy


def test_acar_u_cheaper_than_arena2(runs):
    assert runs["acar_u"].cost < runs["arena_2"].cost


def test_acar_u_avoids_majority_of_full_arena(runs):
    """Paper Fig. 6: full ensembling avoided on >50% of tasks."""
    modes = [o.trace.mode for o in runs["acar_u"].outcomes]
    avoided = 1 - modes.count("full_arena") / len(modes)
    assert avoided > 0.5


def test_sigma_distribution_bimodal(runs):
    """Paper Fig. 1: sigma=0.5 is the rarest bucket."""
    sig = np.array([o.trace.sigma for o in runs["acar_u"].outcomes])
    p0, p05, p1 = [(sig == v).mean() for v in (0.0, 0.5, 1.0)]
    assert p0 > p05 and p1 > p05


def test_headline_accuracies_near_paper(runs):
    """Within 3pp of the paper's Table 1 (calibrated simulator)."""
    paper = {"single_model": 0.454, "arena_2": 0.544,
             "acar_u": 0.556, "arena_3": 0.636}
    for name, target in paper.items():
        assert abs(runs[name].accuracy - target) < 0.03, \
            (name, runs[name].accuracy, target)


def test_retrieval_hurts(runs):
    """Paper Table 2: ACAR-UJ below ACAR-U."""
    assert runs["acar_uj"].accuracy < runs["acar_u"].accuracy


def test_agreement_but_wrong_gap(runs):
    """Paper §6.2: a sigma=0-wrong mass exists and bounds ACAR below
    Arena-3."""
    u = runs["acar_u"].outcomes
    s0_wrong = [o for o in u
                if o.trace.mode == "single_agent" and not o.correct]
    assert len(s0_wrong) / len(u) > 0.03
    assert runs["arena_3"].accuracy - runs["acar_u"].accuracy > 0.02


def test_escalation_by_benchmark(runs):
    """Paper Fig. 5 anchors: code/math escalate, supergpqa mostly
    doesn't."""
    u = runs["acar_u"].outcomes
    by = {}
    for o in u:
        by.setdefault(o.trace.benchmark, []).append(o.trace.mode)
    full = {b: m.count("full_arena") / len(m) for b, m in by.items()}
    single = {b: m.count("single_agent") / len(m) for b, m in by.items()}
    assert full["livecodebench"] > 0.9
    assert full["matharena"] > 0.85
    assert single["supergpqa"] > 0.35


def test_artifacts_written_and_auditable(runs, tmp_path):
    """All five configurations leave verifiable hash-chained stores."""
    from repro.teamllm.artifacts import ArtifactStore
    # the module fixture wrote into its own artifacts dir; re-audit one
    # store from a fresh run with an explicit path
    from repro.core.backends import paper_backends
    from repro.core.orchestrator import run_fixed_mode
    from repro.data.tasks import paper_suite
    store = ArtifactStore(tmp_path / "runs.jsonl")
    run_fixed_mode(paper_suite(seed=0)[:5], paper_backends(),
                   ["claude-sonnet-4"], store=store)
    audit = ArtifactStore(tmp_path / "runs.jsonl").audit()
    assert audit["records"] == 5 and audit["parse_errors"] == 0
