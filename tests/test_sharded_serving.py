"""Sharded serving subsystem: shard placement policy, per-shard pool
invariants, batched route-time extraction, and multi-device
bit-equivalence (subprocess, forced host device count).

Single-device tests run in-process; anything needing a data>1 mesh
goes through the ``forced_devices`` conftest fixture so the suite's
single-device jax state stays unpolluted.
"""
import types

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                    # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.core.extract import extract, extract_batch
from repro.serving.kv_pool import PageAccountingError, PagePoolError
from repro.serving.scheduler import StepPlanner


# ----------------------------------------------------------------------
# StepPlanner.place_shard (least-loaded, free-pages-weighted)
# ----------------------------------------------------------------------
def test_place_shard_picks_most_headroom():
    p = StepPlanner(max_active_rows=8)
    assert p.place_shard([0, 0, 0], [50, 90, 70], [0, 10, 0],
                         row_need=20) == 1
    # reservations count against headroom: 90-80 < 70-0
    assert p.place_shard([0, 0, 0], [50, 90, 70], [0, 80, 0],
                         row_need=20) == 2


def test_place_shard_tie_breaks_to_lowest_index():
    p = StepPlanner(max_active_rows=8)
    assert p.place_shard([0, 0, 0], [60, 60, 60], [0, 0, 0],
                         row_need=10) == 0


def test_place_shard_respects_per_shard_row_cap():
    p = StepPlanner(max_active_rows=2)
    assert p.place_shard([2, 1], [100, 10], [0, 0], row_need=10) == 1
    assert p.place_shard([2, 2], [100, 100], [0, 0], row_need=10) \
        is None


def test_place_shard_none_when_no_budget():
    p = StepPlanner(max_active_rows=8)
    assert p.place_shard([0, 0], [15, 18], [0, 0], row_need=20) is None


def test_place_shard_matches_may_admit():
    """place_shard's per-shard predicate is exactly may_admit."""
    p = StepPlanner(max_active_rows=3)
    rng = np.random.default_rng(0)
    for _ in range(200):
        active = rng.integers(0, 5, size=4).tolist()
        free = rng.integers(0, 60, size=4).tolist()
        reserved = rng.integers(0, 30, size=4).tolist()
        need = int(rng.integers(1, 40))
        got = p.place_shard(active, free, reserved, need)
        admissible = [k for k in range(4)
                      if p.may_admit(active[k], free[k], reserved[k],
                                     need)]
        if got is None:
            assert not admissible
        else:
            assert got in admissible
            headroom = [free[k] - reserved[k] for k in admissible]
            assert free[got] - reserved[got] == max(headroom)


# ----------------------------------------------------------------------
# per-shard pool invariants under shard-local free lists
# ----------------------------------------------------------------------
def _host_only_sharded_server(n_shards=4, num_pages=24,
                              scratch_pages=2, page_size=4,
                              n_model=1):
    """ShardedPagedKVServer host state without device arrays: the
    shard-local pools, scratch regions and prefix caches are all the
    invariants care about."""
    from repro.configs.registry import get_config
    from repro.serving.mesh import ShardedPagedKVServer

    cfg = get_config("smollm-135m", reduced=True).replace(
        dtype="float32", tie_embeddings=True)
    srv = ShardedPagedKVServer.__new__(ShardedPagedKVServer)
    srv.cfg = cfg
    srv.smesh = types.SimpleNamespace(n_shards=n_shards,
                                      n_model=n_model)
    srv.page_size = page_size
    srv.pages = None
    from repro.serving.mesh import _ShardView
    srv.shards = [
        _ShardView(srv, i, cfg, page_size=page_size,
                   prefix_cache_entries=4) for i in range(n_shards)]
    srv._rebuild_host(num_pages, scratch_pages, key=(1, 1, 1, 1))
    return srv


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 5)),
                min_size=1, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_shard_local_pools_track_live_footprint(ops, seed):
    """Random alloc/release traffic spread across shards: every
    shard's pages_in_use equals its own live footprint (scratch +
    outstanding allocations), shard-local free lists never leak pages
    into another shard, and freeing twice raises."""
    rng = np.random.default_rng(seed)
    srv = _host_only_sharded_server()
    live = [[] for _ in range(4)]            # per-shard allocations
    for shard, n in ops:
        pool = srv.shards[shard].pool
        if live[shard] and rng.random() < 0.4:
            pool.release(live[shard].pop())
        elif n <= pool.free_pages:
            live[shard].append(pool.alloc(n))
    for k, sv in enumerate(srv.shards):
        footprint = sv._scratch.size + sum(a.size for a in live[k])
        assert sv.pool.pages_in_use == footprint, f"shard {k}"
        # shard-local ids: every live page id is inside this pool
        for a in live[k]:
            assert all(0 <= p < sv.pool.num_pages for p in a)
    # double free raises and leaves the pool intact
    for k in range(4):
        if live[k]:
            pages = live[k][0]
            srv.shards[k].pool.release(pages)
            before = srv.shards[k].pool.pages_in_use
            with pytest.raises(PageAccountingError):
                srv.shards[k].pool.release(pages)
            assert srv.shards[k].pool.pages_in_use == before
            break


def test_rebuild_refused_while_any_shard_holds_pages():
    srv = _host_only_sharded_server()
    held = srv.shards[2].pool.alloc(3)
    with pytest.raises(PagePoolError):
        srv._rebuild_host(64, 2, key=(2, 2, 2, 2))
    srv.shards[2].pool.release(held)
    srv._rebuild_host(64, 2, key=(2, 2, 2, 2))
    assert all(sv.pool.num_pages == 64 for sv in srv.shards)


def test_shard_pools_are_independent():
    """Exhausting one shard's pool must not touch another's."""
    srv = _host_only_sharded_server(num_pages=8, scratch_pages=2)
    a = srv.shards[0].pool.alloc(6)          # shard 0 full
    assert srv.shards[0].pool.free_pages == 0
    assert srv.shards[1].pool.free_pages == 6
    b = srv.shards[1].pool.alloc(6)
    srv.shards[0].pool.release(a)
    assert srv.shards[1].pool.pages_in_use == 8
    srv.shards[1].pool.release(b)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 4), st.booleans()),
                min_size=1, max_size=60),
       st.integers(0, 2 ** 31 - 1))
def test_2d_placement_preserves_shard_pool_invariants(traffic, seed):
    """Admission-stream placement over a 2-D (data=4, model=2) host
    server: rows land on the shard ``StepPlanner.place_shard`` picks
    and allocate pages from that shard's pool only. At every step each
    data shard's accounting equals its own live footprint, and the
    model axis is invisible to host-side page accounting — the model
    columns slice kv-heads *within* a page, never the page pool — so
    an identically-driven 1-D server produces the same placements and
    the same per-shard counters."""
    rng = np.random.default_rng(seed)
    planner = StepPlanner(max_active_rows=8)
    srv2d = _host_only_sharded_server(num_pages=16, n_model=2)
    srv1d = _host_only_sharded_server(num_pages=16, n_model=1)
    live = [[] for _ in range(4)]            # (alloc_2d, alloc_1d)
    active = [0, 0, 0, 0]
    placements = []
    for need, retire in traffic:
        if retire and any(live):
            k = max(range(4), key=lambda i: len(live[i]))
            a2, a1 = live[k].pop(rng.integers(len(live[k])))
            srv2d.shards[k].pool.release(a2)
            srv1d.shards[k].pool.release(a1)
            active[k] -= 1
            continue
        free = [sv.pool.free_pages for sv in srv2d.shards]
        assert free == [sv.pool.free_pages for sv in srv1d.shards]
        k = planner.place_shard(active, free, [0] * 4, need)
        placements.append(k)
        if k is None:
            continue
        live[k].append((srv2d.shards[k].pool.alloc(need),
                        srv1d.shards[k].pool.alloc(need)))
        active[k] += 1
        for i in range(4):
            footprint = srv2d.shards[i]._scratch.size \
                + sum(a.size for a, _ in live[i])
            assert srv2d.shards[i].pool.pages_in_use == footprint
            assert srv1d.shards[i].pool.pages_in_use == footprint
    # placement is a pure function of the accounting stream: replaying
    # the same decisions against the 1-D server's view picked the same
    # shards (checked inline via the free-list equality above), and the
    # 2-D server still rebuilds once drained
    for k in range(4):
        for a2, a1 in live[k]:
            srv2d.shards[k].pool.release(a2)
            srv1d.shards[k].pool.release(a1)
    srv2d._rebuild_host(32, 2, key=(2, 2, 2, 2))
    assert all(sv.pool.num_pages == 32 for sv in srv2d.shards)


# ----------------------------------------------------------------------
# batched route-time extraction
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["answer: 42", "7 + 5 = 12", "(B)", "so x",
                     "answer: -3.50", "noise 9e2 tail", ""]),
    st.sampled_from(["math", "mcq", "reasoning", "code"])),
    min_size=0, max_size=30))
def test_extract_batch_matches_per_row_extract(pairs):
    """The tick-batched extract is element-wise identical to the
    per-row calls it replaced — batching must never move
    sigma/modes/answers."""
    texts = [t for t, _ in pairs]
    kinds = [k for _, k in pairs]
    assert extract_batch(texts, kinds) == \
        [extract(t, k) for t, k in pairs]


def test_extract_batch_dedupes_duplicate_pairs(monkeypatch):
    """N probe samples decoding the same text are extracted once."""
    import importlib
    ex = importlib.import_module("repro.core.extract")
    calls = []
    real = ex.extract

    def counting(response, kind, canonicalize_code=False):
        calls.append((response, kind))
        return real(response, kind, canonicalize_code)

    monkeypatch.setattr(ex, "extract", counting)
    out = ex.extract_batch(["answer: 7"] * 5 + ["answer: 9"],
                           ["math"] * 6)
    assert out == ["7"] * 5 + ["9"]
    assert len(calls) == 2


def test_extract_batch_length_mismatch_raises():
    with pytest.raises(ValueError):
        extract_batch(["a"], [])


# ----------------------------------------------------------------------
# mesh wrappers (single device, in-process)
# ----------------------------------------------------------------------
def test_serving_mesh_single_device():
    from repro.serving.mesh import ServingMesh
    sm = ServingMesh(data=1)
    assert sm.n_shards == 1
    assert tuple(sm.mesh.axis_names) == ("data",)


def test_serving_mesh_too_many_shards_raises():
    import jax
    from repro.serving.mesh import ServingMesh
    want = len(jax.devices()) + 1
    with pytest.raises(RuntimeError, match="host_platform_device"):
        ServingMesh(data=want)


@pytest.mark.slow
def test_sharded_single_shard_bit_equals_plain_step_loop():
    """data=1 sharded loop (shard_map over one device) emits exactly
    the plain step loop's outputs — the in-process end of the
    bit-equivalence proof (data=4 runs in the subprocess test)."""
    from harness.simulate import paged_zoo
    from repro.configs.acar import ACARConfig
    from repro.data.tasks import Task
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    rng = np.random.default_rng(3)
    tasks = []
    for i in range(8):
        digits = "".join(str(rng.integers(10)) for _ in range(16))
        tasks.append(Task(task_id=f"sh{i}", benchmark="x",
                          kind="math", text=f"{digits} + 1 = ",
                          gold="0", difficulty=0.0))
    probe, ensemble = paged_zoo(seed=0)
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)
    plain = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
    res_p = plain.run_stepped(tasks, policy, chunk_tokens=7)
    sharded = BatchedACAREngine(acfg, probe, ensemble,
                                max_new_tokens=4)
    res_s = sharded.run_stepped(tasks, policy, chunk_tokens=7,
                                data_shards=1)
    np.testing.assert_array_equal(res_p.sigma, res_s.sigma)
    np.testing.assert_array_equal(res_p.modes, res_s.modes)
    assert res_p.final_answers == res_s.final_answers
    assert res_p.probe_texts == res_s.probe_texts
    assert res_p.member_answers == res_s.member_answers


@pytest.mark.slow
def test_sharded_data4_bit_equals_single_device(forced_devices):
    """The real thing: a 4-shard mesh (forced host devices, subprocess
    so the in-process jax state stays single-device) serves a
    duplicate-bearing stream bit-identically to the single-device
    step loop, balances placement, and leaks no pages (per-shard
    pools end at scratch + prefix-cache footprint)."""
    out = forced_devices("""
import numpy as np
from harness.simulate import paged_zoo
from repro.configs.acar import ACARConfig
from repro.data.tasks import Task
from repro.serving import (
    AdmissionQueue, BatchedACAREngine, MicroBatchPolicy)
from repro.serving.mesh import ServingMesh
from repro.serving.scheduler import StepPlanner
from repro.serving.step_loop import ShardedStepLoopRunner

rng = np.random.default_rng(1)
tasks = []
for i in range(12):
    if tasks and rng.random() < 0.25:
        tasks.append(tasks[int(rng.integers(len(tasks)))]); continue
    digits = ''.join(str(rng.integers(10)) for _ in range(16))
    tasks.append(Task(task_id=f't{i}', benchmark='x', kind='math',
                      text=f'{digits} + 1 = ', gold='0',
                      difficulty=0.0))
probe, ensemble = paged_zoo(seed=0)
acfg = ACARConfig(probe_temperature=0.9, seed=0)
policy = MicroBatchPolicy(max_batch_size=4, max_batch_tokens=1 << 20)
e1 = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
r1 = e1.run_stepped(tasks, policy, chunk_tokens=7)

e2 = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
queue = AdmissionQueue(policy)
for t in tasks:
    queue.submit(t)
runner = ShardedStepLoopRunner(
    e2, queue, StepPlanner(chunk_tokens=7, max_active_rows=4),
    ServingMesh(data=4))
runner.run()
rows = [runner.done_rows[i] for i in range(len(tasks))]
np.testing.assert_array_equal(
    r1.sigma, np.asarray([r.sigma for r in rows], np.float32))
np.testing.assert_array_equal(
    r1.modes, np.asarray([r.mode for r in rows], np.int32))
assert r1.final_answers == [r.final_answer for r in rows]
assert r1.probe_texts == [r.probe_texts for r in rows]
# placement spreads rows and covers every admission
placed = [runner.metrics.get('acar_shard_placements_total',
                             shard=str(k)) for k in range(4)]
assert sum(placed) == len(tasks)
assert sum(1 for p in placed if p > 0) >= 2
# per-shard page hygiene: nothing outlives the stream except each
# shard's scratch region and its prefix-cache retention
for srv in runner._sharded.values():
    for sv in srv.shards:
        cache = sum(e.pages_held for e in sv._prefix.values())
        assert sv.pool.pages_in_use == sv._scratch.size + cache, (
            sv.stats.model, sv.index)
print('SHARDED-OK', runner.stats.ticks)
""")
    assert "SHARDED-OK" in out
