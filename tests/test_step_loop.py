"""Step-level continuous batching: admission wiring, planner policy,
and wave<->step bit-equivalence on small real-model streams.

``AdmissionQueue.ready()`` is the single admission source for both
execution styles now — ``drain_batches`` jumps a virtual clock to each
fill-or-timeout instant, and the step loop polls it every tick — so
the regression tests here pin the fill-or-timeout budget under bursty
tick patterns (the dead-path bug this PR fixes: ready() existed but
nothing called it).
"""
import numpy as np
import pytest

from repro.data.tasks import Task
from repro.serving.queue import AdmissionQueue, MicroBatchPolicy
from repro.serving.scheduler import StepPlanner


def mk_task(i, text="1 + 1 = "):
    return Task(task_id=f"s-{i:03d}", benchmark="arithmetic",
                kind="math", text=text, gold="2", difficulty=0.0)


# ----------------------------------------------------------------------
# AdmissionQueue.ready() as the single admission source
# ----------------------------------------------------------------------
def test_pop_matches_form_batch_numbering():
    """pop() and form_batch() draw admission indices from one
    counter — row numbering (and therefore sampling key streams) is
    identical however the stream is admitted."""
    q1 = AdmissionQueue(MicroBatchPolicy(max_batch_size=4))
    q2 = AdmissionQueue(MicroBatchPolicy(max_batch_size=4))
    for i in range(6):
        q1.submit(mk_task(i))
        q2.submit(mk_task(i))
    flat = [r for b in q1.drain_batches() for r in b.requests]
    popped = [q2.pop() for _ in range(6)]
    assert [r.admission_index for r in flat] == \
        [r.admission_index for r in popped] == list(range(6))
    assert [r.task.task_id for r in flat] == \
        [r.task.task_id for r in popped]


def test_ready_fill_trigger_under_burst():
    """A burst filling the size budget makes ready() fire at the
    burst's arrival tick, not later."""
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=4,
                                        max_wait_ticks=100))
    for i in range(4):
        q.submit(mk_task(i), arrival_time=50)
    assert q.next_ready_at() == 50
    assert q.ready(now=50)


def test_ready_timeout_budget_holds_under_bursty_ticks():
    """Bursty arrivals smaller than the batch budget: every request
    must become admissible within max_wait_ticks of its burst's
    arrival — the fill-or-timeout guarantee."""
    pol = MicroBatchPolicy(max_batch_size=8, max_wait_ticks=10)
    q = AdmissionQueue(pol)
    bursts = [(0, 3), (4, 2), (37, 3), (38, 1)]    # (tick, size)
    for t, size in bursts:
        for i in range(size):
            q.submit(mk_task(t * 10 + i), arrival_time=t)
    # simulate a streaming loop ticking through time
    admitted_at = {}
    now = 0
    while len(q):
        if q.ready(now):
            batch = q.form_batch(now)
            for r in batch.requests:
                admitted_at[r.task.task_id] = now
        else:
            now += 1
    for t, size in bursts:
        for i in range(size):
            tid = mk_task(t * 10 + i).task_id
            assert admitted_at[tid] - t <= pol.max_wait_ticks, \
                f"{tid} waited past the fill-or-timeout budget"


def test_drain_batches_uses_ready_clock():
    """drain_batches forms the exact batch sequence a streaming loop
    would: the under-sized tail batch forms at its timeout instant."""
    pol = MicroBatchPolicy(max_batch_size=4, max_wait_ticks=7)
    q = AdmissionQueue(pol)
    for i in range(5):
        q.submit(mk_task(i), arrival_time=i)
    batches = q.drain_batches()
    assert [len(b) for b in batches] == [4, 1]
    # the full batch was ready the moment its last member arrived
    # (tick 3), so it forms as soon as the drain starts (the queue
    # clock is already at 5 after the submissions); the under-sized
    # tail batch waits for its oldest member's timeout
    assert batches[0].formed_at == 5
    assert batches[1].formed_at == 4 + pol.max_wait_ticks


def test_next_ready_at_empty_queue():
    assert AdmissionQueue().next_ready_at() is None


# ----------------------------------------------------------------------
# StepPlanner policy
# ----------------------------------------------------------------------
def test_planner_chunk_span():
    p = StepPlanner(chunk_tokens=8, max_active_rows=4)
    assert p.chunk_span(0, 20) == 8
    assert p.chunk_span(16, 20) == 4
    assert p.chunk_span(8, 9) == 1


def test_planner_decode_bucket_powers_of_two():
    p = StepPlanner()
    assert [p.decode_bucket(k) for k in (1, 2, 3, 5, 8)] == \
        [1, 2, 4, 8, 8]


def test_planner_admission_gate():
    p = StepPlanner(chunk_tokens=8, max_active_rows=2)
    assert p.may_admit(0, free_pages=100, reserved_pages=0,
                       row_need=20)
    # active-row cap
    assert not p.may_admit(2, free_pages=100, reserved_pages=0,
                           row_need=20)
    # page budget net of outstanding reservations
    assert not p.may_admit(1, free_pages=100, reserved_pages=90,
                           row_need=20)
    assert p.may_admit(1, free_pages=100, reserved_pages=80,
                       row_need=20)


# ----------------------------------------------------------------------
# wave <-> step bit-equivalence (real tiny models)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_step_loop_bit_equals_wave():
    """Long prompts straddling chunk boundaries, duplicates, sampled
    probe temperature: the step loop emits the exact per-task outputs
    the wave engine does, and retires pages leak-free."""
    from harness.simulate import paged_zoo
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    rng = np.random.default_rng(0)
    tasks = []
    for i in range(12):
        if tasks and rng.random() < 0.25:
            tasks.append(tasks[int(rng.integers(len(tasks)))])
            continue
        digits = "".join(str(rng.integers(10)) for _ in range(20))
        tasks.append(Task(task_id=f"t{i}", benchmark="x", kind="math",
                          text=f"{digits} + 1 = ", gold="0",
                          difficulty=0.0))
    probe, ensemble = paged_zoo(seed=0)
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)

    wave = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=5)
    res_w = wave.run_queued(tasks, policy)
    step = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=5)
    res_s = step.run_stepped(tasks, policy, chunk_tokens=7)

    np.testing.assert_array_equal(res_w.sigma, res_s.sigma)
    np.testing.assert_array_equal(res_w.modes, res_s.modes)
    assert res_w.final_answers == res_s.final_answers
    assert res_w.probe_texts == res_s.probe_texts
    assert res_w.member_answers == res_s.member_answers
    # pages: nothing outlives the stream except scratch + prefix cache
    for srv in step._kv_servers.values():
        cache = sum(e.pages_held for e in srv._prefix.values())
        assert srv.pool.pages_in_use == srv._scratch.size + cache

    # step metrics exposed (satellite: planner decisions observable)
    m = res_s.metrics
    assert m.get("acar_step_admissions_total") == len(tasks)
    assert m.get("acar_step_rows_active", phase="done") == len(tasks)
    assert res_s.step.prefill_chunks > 0
    rendered = m.render()
    assert "acar_prefill_chunks_total" in rendered
    assert "acar_step_bucket_occupancy" in rendered


@pytest.mark.slow
def test_step_loop_respects_page_budget_admission():
    """With a tiny active cap the loop still serves everything —
    admission defers rather than exhausting the pool."""
    from harness.simulate import paged_zoo
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy

    tasks = [mk_task(i, text=f"{i % 10} + 1 = ") for i in range(6)]
    probe, ensemble = paged_zoo(seed=0)
    acfg = ACARConfig(probe_temperature=0.0, seed=0)
    eng = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
    res = eng.run_stepped(
        tasks, MicroBatchPolicy(max_batch_size=6,
                                max_batch_tokens=1 << 20),
        chunk_tokens=4, max_active_rows=2)
    assert len(res.final_answers) == 6
    assert max(res.batch_sizes) <= 2


@pytest.mark.slow
def test_step_loop_dense_member_fallback_bit_equals_wave():
    """A non-paged ensemble member (hybrid stack) takes the dense
    one-shot fallback inside the step loop — still bit-identical to
    the wave path, because both decode it with the same per-row key
    streams."""
    import jax
    from repro.configs.registry import get_config
    from repro.configs.acar import ACARConfig
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.models.transformer import paged_supported
    from repro.serving import (
        BatchedACAREngine, MicroBatchPolicy, ZooModel)

    def mk(arch, i):
        cfg = get_config(arch, reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(i))
        return ZooModel(name=f"{arch}-{i}", cfg=cfg, params=prm)

    probe = mk("smollm-135m", 0)
    hybrid = mk("recurrentgemma-2b", 1)
    assert not paged_supported(hybrid.cfg)
    ensemble = [mk("smollm-135m", 2), hybrid,
                ZooModel(name="twin", cfg=probe.cfg,
                         params=probe.params)]
    tasks = [mk_task(i, text=f"{i % 10} + 2 = ") for i in range(4)]
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)
    wave = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
    res_w = wave.run_queued(tasks, policy)
    step = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
    res_s = step.run_stepped(tasks, policy, chunk_tokens=3)
    assert res_w.final_answers == res_s.final_answers
    assert res_w.member_answers == res_s.member_answers
    np.testing.assert_array_equal(res_w.modes, res_s.modes)
