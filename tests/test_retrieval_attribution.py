"""Jungler experience store (§6.1) + attribution machinery (§6.3)."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                          # seeded fallback shim
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.core.attribution import (
    coalition_accuracy, leave_one_out, proxy_agreement, proxy_entropy,
    proxy_similarity, proxy_vs_truth_correlation, shapley)
from repro.core.retrieval import Experience, ExperienceStore, embed_text
from repro.teamllm.trace import ModelResponse


def mr(model, answer):
    return ModelResponse(model=model, response=f"answer: {answer}",
                         answer=answer, cost=0.0)


# ----------------------------------------------------------------------
# retrieval
# ----------------------------------------------------------------------
def test_embed_deterministic_and_normalised():
    v1 = embed_text("what is 2 + 2")
    v2 = embed_text("what is 2 + 2")
    np.testing.assert_array_equal(v1, v2)
    assert np.linalg.norm(v1) == pytest.approx(1.0, abs=1e-5)


def test_self_similarity_is_max():
    store = ExperienceStore()
    store.add(Experience("compute the derivative of x^2", "2x", True,
                         "math"))
    store.add(Experience("capital of france", "paris", True, "qa"))
    res = store.query("compute the derivative of x^2", top_k=1)
    assert res[0][0].answer == "2x"
    assert res[0][1] == pytest.approx(1.0, abs=1e-5)


def test_threshold_filters_weak_matches():
    store = ExperienceStore()
    store.add(Experience("alpha beta gamma", "x", True, "b"))
    weak = store.query("completely unrelated words here", threshold=0.7)
    assert weak == []
    any_match = store.query("completely unrelated words here",
                            threshold=-1.0)
    assert len(any_match) == 1


def test_similarity_stats_shape():
    store = ExperienceStore()
    for i in range(5):
        store.add(Experience(f"task number {i} about topic", str(i),
                             True, "b"))
    stats = store.similarity_stats(["task about topic", "zzz qqq"])
    assert 0 <= stats["hit_rate"] <= 1
    assert len(stats["similarities"]) <= 2


# ----------------------------------------------------------------------
# attribution ground truth
# ----------------------------------------------------------------------
def test_loo_identifies_pivotal_model():
    # c is pivotal: without it the judge picks "wrong"
    rs = [mr("a", "wrong"), mr("b", "gold"), mr("c", "gold")]
    loo = leave_one_out(rs, "t", gold="gold")
    assert loo["b"] > 0 or loo["c"] > 0
    assert loo["a"] <= 0


def test_shapley_efficiency():
    """sum_i phi_i = v(N) - v(empty)."""
    rs = [mr("a", "x"), mr("b", "gold"), mr("c", "gold")]
    phi = shapley(rs, "t", gold="gold")
    total = sum(phi.values())
    v_full = coalition_accuracy(rs, "t", "gold")
    assert total == pytest.approx(v_full - 0.0, abs=1e-9)


def test_shapley_symmetry():
    rs = [mr("a", "gold"), mr("b", "gold"), mr("c", "z")]
    phi = shapley(rs, "t", gold="gold")
    assert phi["a"] == pytest.approx(phi["b"], abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["gold", "w1", "w2"]),
                min_size=2, max_size=3))
def test_shapley_efficiency_property(answers):
    rs = [mr(f"m{i}", a) for i, a in enumerate(answers)]
    phi = shapley(rs, "task-7", gold="gold")
    assert sum(phi.values()) == pytest.approx(
        coalition_accuracy(rs, "task-7", "gold"), abs=1e-9)


# ----------------------------------------------------------------------
# proxies (the signals the paper shows fail)
# ----------------------------------------------------------------------
def test_proxies_produce_per_model_values():
    rs = [mr("a", "x"), mr("b", "y"), mr("c", "x")]
    for proxy in (proxy_entropy(rs), proxy_agreement(rs),
                  proxy_similarity(rs, "x")):
        assert set(proxy) == {"a", "b", "c"}


def test_proxy_agreement_values():
    rs = [mr("a", "x"), mr("b", "x"), mr("c", "y")]
    ag = proxy_agreement(rs)
    assert ag["a"] == pytest.approx(0.5)
    assert ag["c"] == 0.0


def test_correlation_helper():
    t = [{"a": 1.0, "b": 0.0}, {"a": 0.0, "b": 1.0}]
    assert proxy_vs_truth_correlation(t, t) == pytest.approx(1.0)
    flipped = [{"a": 0.0, "b": 1.0}, {"a": 1.0, "b": 0.0}]
    assert proxy_vs_truth_correlation(t, flipped) == pytest.approx(-1.0)
    assert proxy_vs_truth_correlation([], []) == 0.0
