"""Process-stable PRNG seeding (deterministic-execution invariant).

``JaxModelBackend`` derives its generation key from the task id. That
derivation must not depend on PYTHONHASHSEED — builtin str hashing is
salted per process, so two identical runs in different processes would
otherwise draw different keys.
"""
import hashlib
import os
import subprocess
import sys

from repro.teamllm.fingerprint import stable_fingerprint


def _run(expr: str, hashseed: str) -> str:
    env = dict(os.environ, PYTHONHASHSEED=hashseed,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    return subprocess.check_output(
        [sys.executable, "-c", expr], env=env, text=True).strip()


def test_stable_fingerprint_is_sha_derived():
    h = hashlib.sha256(b"task-123").digest()
    assert stable_fingerprint("task-123") == \
        int.from_bytes(h[:8], "little") % (1 << 31)
    assert 0 <= stable_fingerprint("x", bits=16) < (1 << 16)


def test_stable_fingerprint_survives_hashseed():
    expr = ("from repro.teamllm.fingerprint import stable_fingerprint;"
            "print(stable_fingerprint('gsm8k-0042'))")
    a = _run(expr, "0")
    b = _run(expr, "12345")
    assert a == b == str(stable_fingerprint("gsm8k-0042"))


def test_builtin_hash_would_have_failed():
    """Sanity: the quantity the old code used really does vary with
    PYTHONHASHSEED — this test guards the fix's motivation."""
    expr = "print(abs(hash('gsm8k-0042')) % (1 << 31))"
    assert _run(expr, "0") != _run(expr, "12345")
