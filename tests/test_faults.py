"""Fault tolerance: deterministic injection, member degradation,
write-ahead journal recovery, and the chaos property.

Fast tests pin the pure machinery (FaultSpec/FaultPlan/FaultInjector,
the ``degrade_mode`` ladder, ArtifactStore torn-tail recovery, the
StepJournal event round-trip). Slow tests drive the real-model step
loop through injected faults and assert the robustness contract:
requeues preserve admission indices (and therefore outcomes), NaN
members quarantine and routes degrade without dropping rows, SLO
aborts are traced null-answer retirements, a killed journaled run
recovers bit-identically, and random seeded fault plans (the chaos
property) never leak pages, never lose rows, and replay identically.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.core.routing import degrade_mode
from repro.serving.faults import (
    SITES, FaultInjector, FaultPlan, FaultSpec, SimulatedCrash)
from repro.serving.journal import StepJournal
from repro.serving.metrics import (
    MEMBER_QUARANTINED, MEMBER_RETRIES, ROUTES_DEGRADED,
    ROW_DEADLINE_ABORTS, STEP_REQUEUES)
from repro.teamllm.artifacts import ArtifactStore, ChainCorruption

_ZOO = {}


def _zoo():
    if "z" not in _ZOO:
        from harness.simulate import paged_zoo
        _ZOO["z"] = paged_zoo(seed=0)
    return _ZOO["z"]


def _tasks(n, seed=0, duplicate_rate=0.2):
    from harness.simulate import long_prompt_workload
    return long_prompt_workload(n, 20, seed=seed,
                                duplicate_rate=duplicate_rate)


def _serve(tasks, plan=None, **kw):
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    probe, ensemble = _zoo()
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)
    eng = BatchedACAREngine(acfg, probe, ensemble, max_new_tokens=4)
    res = eng.run_stepped(tasks, policy, chunk_tokens=7, faults=plan,
                          **kw)
    return eng, res


def _assert_no_leaks(eng):
    """Drain-time page accounting: after dropping the prefix cache
    every server must hold exactly its scratch pages (the cache's
    retained footprint legitimately differs between faulted and
    fault-free runs, the scratch floor does not)."""
    for srv in eng._kv_servers.values():
        srv.drop_prefix_cache()
        assert srv.pool.pages_in_use == srv._scratch.size


# ----------------------------------------------------------------------
# fault plan / injector machinery
# ----------------------------------------------------------------------
def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(tick=0, site="not-a-site")
    with pytest.raises(ValueError):
        FaultSpec(tick=-1, site="crash")
    with pytest.raises(ValueError):
        FaultSpec(tick=0, site="crash", count=0)
    assert FaultSpec(tick=3, site="member_nan", model="m1").count == 1


def test_injector_fires_at_or_after_tick_consume_once():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(tick=5, site="member_nan", model="m1"),)))
    assert inj.fire("member_nan", 4, model="m1") is None
    assert inj.fire("member_nan", 7, model="m2") is None  # wrong model
    sp = inj.fire("member_nan", 7, model="m1")
    assert sp is not None and sp.tick == 5
    # consumed: never fires again
    assert inj.fire("member_nan", 8, model="m1") is None
    assert inj.exhausted


def test_injector_wildcards_and_counts():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(tick=0, site="admit_alloc", count=2),
        FaultSpec(tick=0, site="shard_loss", shard=1),)))
    assert inj.fire("admit_alloc", 0) is not None
    assert inj.fire("admit_alloc", 3) is not None
    assert inj.fire("admit_alloc", 4) is None          # count drained
    assert inj.fire("shard_loss", 1, shard=0) is None  # wrong shard
    assert inj.fire("shard_loss", 1, shard=1) is not None
    assert inj.exhausted and len(inj.fired) == 3


def test_injector_replay_is_deterministic():
    plan = FaultPlan.generate(11, n_faults=4, max_tick=20,
                              models=["a", "b"], shards=2)
    calls = [("member_nan", 3, "a", None), ("shard_loss", 5, None, 0),
             ("admit_alloc", 8, None, None),
             ("member_launch", 12, "b", None),
             ("member_nan", 19, "b", None)]
    fired = []
    for _ in range(2):
        inj = FaultInjector(plan)
        for site, tick, model, shard in calls:
            inj.fire(site, tick, model=model, shard=shard)
        fired.append(inj.fired)
    assert fired[0] == fired[1]


def test_generate_is_seeded_and_respects_topology():
    a = FaultPlan.generate(7, models=["m1"], shards=2)
    b = FaultPlan.generate(7, models=["m1"], shards=2)
    assert a.specs == b.specs
    assert a.specs != FaultPlan.generate(8, models=["m1"],
                                         shards=2).specs
    # no shards / no models: those sites never appear
    lean = FaultPlan.generate(7, n_faults=16)
    assert all(sp.site == "admit_alloc" for sp in lean.specs)
    # terminal sites excluded unless asked for
    assert all(sp.site not in ("crash", "artifact_append")
               for sp in FaultPlan.generate(7, n_faults=16,
                                            models=["m1"],
                                            shards=4).specs)


def test_degrade_mode_ladder():
    # full arena survives any healthy member
    assert degrade_mode(2, [False, False, True]) == 2
    # arena-lite needs a healthy member among the first two
    assert degrade_mode(1, [False, True, True]) == 1
    assert degrade_mode(1, [True, False, False]) == 1
    # both arena-lite members down: 1 -> 0
    assert degrade_mode(1, [False, False, True]) == 0
    # everything down: -> 0
    assert degrade_mode(2, [False, False, False]) == 0
    assert degrade_mode(1, [False, False, False]) == 0
    # mode 0 never moves
    assert degrade_mode(0, [True, True, True]) == 0


# ----------------------------------------------------------------------
# artifact store crash safety + journal round trip
# ----------------------------------------------------------------------
def test_artifact_store_recovers_torn_tail(tmp_path):
    p = tmp_path / "chain.jsonl"
    store = ArtifactStore(p)
    for i in range(3):
        store.append({"event": "x", "i": i})
    head = store.head
    # a kill mid-append leaves a torn, newline-less final line
    with p.open("a") as f:
        f.write('{"payload": {"event": "x", "i": 3}, "tru')
    reopened = ArtifactStore(p)
    assert reopened.torn_recovered
    assert len(reopened) == 3
    assert reopened.head == head
    assert reopened.audit()["ok"]
    # the store still appends after recovery
    reopened.append({"event": "x", "i": 3})
    assert ArtifactStore(p).audit()["records"] == 4


def test_artifact_store_rejects_tampered_complete_line(tmp_path):
    p = tmp_path / "chain.jsonl"
    store = ArtifactStore(p)
    store.append({"event": "x", "i": 0})
    store.append({"event": "x", "i": 1})
    lines = p.read_text().splitlines()
    assert '"i":1' in lines[-1]       # stable_json: no spaces
    lines[-1] = lines[-1].replace('"i":1', '"i":9')
    p.write_text("\n".join(lines) + "\n")
    # a tampered-but-complete line is corruption, not a torn tail
    with pytest.raises(ChainCorruption):
        ArtifactStore(p)


def test_journal_round_trip(tmp_path):
    p = tmp_path / "journal.jsonl"
    j = StepJournal(p)
    j.admit(0, "r-0", 1)
    j.admit(1, "r-1", 2)
    j.emit(3, "m1", [[0, 100, 1, 0, [5]], [1, 101, 1, 1, [6]]])
    j.fault({"kind": "member_retry", "model": "m1"}, 4)
    j.retire({"adm": 0, "task_id": "t0", "sigma": 0.5, "mode": 1,
              "probe_texts": ["a"], "probe_answers": ["a"],
              "member_answers": ["a", None, None],
              "final_answer": "a", "aborted": None,
              "timeline": [0, 1, 9]}, 9)
    state = StepJournal.load(p)
    assert state.admitted == {0, 1}
    assert set(state.retired) == {0}
    assert state.retired[0]["final_answer"] == "a"
    assert state.retired[0]["timeline"] == [0, 1, 9]
    assert [f["kind"] for f in state.faults] == ["member_retry"]
    assert state.records == 5
    assert not state.torn_recovered
    assert state.head == j.head


def test_journal_torn_append_kills_and_recovers(tmp_path):
    p = tmp_path / "journal.jsonl"
    inj = FaultInjector(FaultPlan.crash_at(2, torn=True))
    j = StepJournal(p, injector=inj)
    j.admit(0, "r-0", 0)
    j.retire({"adm": 0, "final_answer": "a"}, 1)
    head = j.head
    with pytest.raises(SimulatedCrash):
        j.admit(1, "r-1", 2)
    # the torn prefix is on disk, newline-less
    assert not p.read_text().endswith("\n")
    state = StepJournal.load(p)
    assert state.torn_recovered
    assert state.records == 2
    assert state.head == head
    assert state.admitted == {0}


# ----------------------------------------------------------------------
# step-loop behaviour under injected faults (real models, small)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_requeue_preserves_admission_index_and_outcomes():
    """An admission-time ``PoolExhausted`` requeues the row with its
    original admission index, so sampling key streams — and therefore
    every judge-visible output — match the fault-free run."""
    tasks = _tasks(8, seed=1)
    _, base = _serve(tasks)
    plan = FaultPlan(specs=(
        FaultSpec(tick=1, site="admit_alloc", count=2),))
    eng, res = _serve(tasks, plan)
    assert res.step.requeues >= 1
    assert res.metrics.get(STEP_REQUEUES) >= 1
    assert any(f["kind"] == "requeued" for f in res.faults)
    np.testing.assert_array_equal(base.sigma, res.sigma)
    np.testing.assert_array_equal(base.modes, res.modes)
    assert base.final_answers == res.final_answers
    assert base.member_answers == res.member_answers
    _assert_no_leaks(eng)


@pytest.mark.slow
def test_member_nan_quarantine_degrades_routes_and_keeps_serving():
    """NaN logits on both arena-lite members quarantine them mid
    stream; every row still retires with an answer, arena-lite routes
    degrade to the probe consensus, and the whole degradation is
    metered and traced."""
    tasks = _tasks(16, seed=2)
    probe, ensemble = _zoo()
    names = [m.name for m in ensemble]
    plan = FaultPlan(specs=(
        FaultSpec(tick=3, site="member_nan", model=names[0]),
        FaultSpec(tick=5, site="member_nan", model=names[1]),))
    eng, res = _serve(tasks, plan)
    assert all(a is not None for a in res.final_answers)
    for m in names[:2]:
        assert res.metrics.get(MEMBER_QUARANTINED, model=m) == 1.0
    degraded = sum(
        res.metrics.get(ROUTES_DEGRADED,
                        **{"from": str(f), "to": str(t)})
        for f in (1, 2) for t in (0, 1) if t < f)
    assert degraded >= 1
    kinds = {f["kind"] for f in res.faults}
    assert {"member_quarantined", "route_degraded"} <= kinds
    # deterministic replay of the degraded run
    _, res2 = _serve(tasks, plan)
    assert res.final_answers == res2.final_answers
    assert res.member_answers == res2.member_answers
    assert res.faults == res2.faults
    _assert_no_leaks(eng)


@pytest.mark.slow
def test_member_launch_retries_then_quarantines():
    """Transient launch failures retry with exponential virtual-clock
    backoff; exhausting the retry budget quarantines the member."""
    tasks = _tasks(8, seed=3)
    probe, ensemble = _zoo()
    name = ensemble[0].name
    plan = FaultPlan(specs=(
        FaultSpec(tick=2, site="member_launch", model=name,
                  count=10),), max_retries=2)
    eng, res = _serve(tasks, plan)
    assert res.metrics.get(MEMBER_RETRIES, model=name) >= 1
    assert res.metrics.get(MEMBER_QUARANTINED, model=name) == 1.0
    kinds = [f["kind"] for f in res.faults]
    assert "member_retry" in kinds and "member_quarantined" in kinds
    assert all(a is not None for a in res.final_answers)
    _assert_no_leaks(eng)


@pytest.mark.slow
def test_slo_deadline_aborts_are_traced_null_retirements():
    tasks = _tasks(6, seed=4)
    eng, res = _serve(tasks, FaultPlan(slo_deadline=1))
    assert res.step.aborted == len(tasks)
    assert all(a is None for a in res.final_answers)
    assert res.metrics.get(ROW_DEADLINE_ABORTS) == len(tasks)
    aborted = [f for f in res.faults if f["kind"] == "row_aborted"]
    assert sorted(f["admission"] for f in aborted) == \
        list(range(len(tasks)))
    assert all(f["reason"] == "slo_deadline" for f in aborted)
    _assert_no_leaks(eng)


@pytest.mark.slow
def test_crash_recover_is_bit_identical(tmp_path):
    """Kill a journaled run mid-stream; ``recover()`` restores retired
    rows verbatim and re-executes the rest to the uninterrupted run's
    exact outputs."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    tasks = _tasks(10, seed=5)
    probe, ensemble = _zoo()
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)

    def _eng():
        return BatchedACAREngine(acfg, probe, ensemble,
                                 max_new_tokens=4)

    base = _eng().run_stepped(tasks, policy, chunk_tokens=7)
    jp = tmp_path / "journal.jsonl"
    with pytest.raises(SimulatedCrash):
        _eng().run_stepped(
            tasks, policy, chunk_tokens=7, journal_path=jp,
            faults=FaultPlan.crash_at(base.step.ticks * 3 // 4))
    res = _eng().recover(tasks, policy, journal_path=jp,
                         chunk_tokens=7)
    assert res.restored_rows > 0
    np.testing.assert_array_equal(base.sigma, res.sigma)
    np.testing.assert_array_equal(base.modes, res.modes)
    assert base.final_answers == res.final_answers
    assert base.member_answers == res.member_answers
    assert base.probe_texts == res.probe_texts


@pytest.mark.slow
def test_recover_preserves_schedule_side_channel(tmp_path):
    """The non-hashed scheduling metadata (per-row arrival / admitted /
    retired ticks, journaled alongside each retirement) survives
    ``recover()``: restored rows carry their journaled timeline
    verbatim, while re-executed rows regenerate theirs from actual
    re-execution — a recovered run never fabricates scheduling history
    for work it re-ran."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    tasks = _tasks(10, seed=6)
    probe, ensemble = _zoo()
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    policy = MicroBatchPolicy(max_batch_size=4,
                              max_batch_tokens=1 << 20)

    def _eng():
        return BatchedACAREngine(acfg, probe, ensemble,
                                 max_new_tokens=4)

    base = _eng().run_stepped(tasks, policy, chunk_tokens=7)
    jp = tmp_path / "journal.jsonl"
    with pytest.raises(SimulatedCrash):
        _eng().run_stepped(
            tasks, policy, chunk_tokens=7, journal_path=jp,
            faults=FaultPlan.crash_at(base.step.ticks * 3 // 4))
    state = StepJournal.load(jp)
    assert state.retired                  # crash landed mid-stream
    res = _eng().recover(tasks, policy, journal_path=jp,
                         chunk_tokens=7)
    assert res.restored_rows == len(state.retired)

    # restored rows: the journaled timeline verbatim — which is also
    # the uninterrupted run's (the killed run was identical up to the
    # crash), not the restore tick
    for adm, rec in state.retired.items():
        assert res.step.timeline[adm] == tuple(rec["timeline"])
        assert res.step.timeline[adm] == base.step.timeline[adm]

    # re-executed rows: no journal entry to copy — arrival comes from
    # the (deterministic) stream and admission/retirement ticks are
    # real ticks the recovered run actually stepped through
    reexec = [a for a in base.step.timeline if a not in state.retired]
    assert reexec
    for adm in reexec:
        arr, admitted, retired = res.step.timeline[adm]
        assert arr == base.step.timeline[adm][0]
        assert 0 <= admitted <= retired
    assert len(res.step.timeline) == len(tasks)


# ----------------------------------------------------------------------
# chaos property: random seeded fault plans
# ----------------------------------------------------------------------
@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=99_999))
def test_chaos_random_fault_plans_lose_nothing(seed):
    """For any generated fault plan: no page leaks, no lost rows
    (every admitted task retires with an answer or a traced abort),
    and an identical-plan replay produces identical outcomes and
    fault events."""
    tasks = _tasks(6, seed=seed % 13, duplicate_rate=0.25)
    probe, ensemble = _zoo()
    plan = FaultPlan.generate(seed, n_faults=3, max_tick=40,
                              models=[m.name for m in ensemble])
    eng, res = _serve(tasks, plan)
    _assert_no_leaks(eng)
    for i in range(len(tasks)):
        assert (res.final_answers[i] is not None
                or any(f["kind"] == "row_aborted"
                       and f["admission"] == i
                       for f in (res.faults or []))), \
            f"row {i} neither answered nor abort-traced (seed {seed})"
    _, res2 = _serve(tasks, plan)
    assert res.final_answers == res2.final_answers
    assert res.member_answers == res2.member_answers
    np.testing.assert_array_equal(res.sigma, res2.sigma)
    assert (res.faults or []) == (res2.faults or [])
