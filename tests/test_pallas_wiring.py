"""ModelConfig.use_pallas routes model code through kernels/ops.py.

Off-TPU the ops dispatch to the jnp oracles, so the flag must be
output-identical on CPU (the TPU path is validated per-kernel in
tests/test_kernels.py via interpret mode)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models import params as params_lib
from repro.models import transformer as T

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow

ARCHS = ["llama3-8b", "falcon-mamba-7b", "recurrentgemma-2b",
         "deepseek-7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_use_pallas_forward_identical_on_cpu(arch):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    f0, _ = T.forward(cfg, params, toks)
    f1, _ = T.forward(cfg.replace(use_pallas=True), params, toks)
    assert float(jnp.max(jnp.abs(f0 - f1))) < 1e-5


def test_use_pallas_decode_identical_on_cpu():
    cfg = get_config("llama3-8b", reduced=True).replace(dtype="float32")
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    _, cache = T.prefill(cfg, params, toks[:, :16], cache_len=17)
    d0, _ = T.decode_step(cfg, params, cache, toks[:, 16],
                          jnp.int32(16))
    d1, _ = T.decode_step(cfg.replace(use_pallas=True), params, cache,
                          toks[:, 16], jnp.int32(16))
    assert float(jnp.max(jnp.abs(d0 - d1))) < 1e-5
