"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernels target TPU; interpret executes the body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fused_swiglu import fused_swiglu
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.selective_scan import selective_scan

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def tol_for(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,dk,s,blk", [
    (1, 4, 4, 64, 256, 128),      # MHA
    (2, 8, 2, 128, 512, 128),     # GQA
    (2, 8, 1, 128, 512, 256),     # MQA
    (1, 16, 8, 64, 1024, 512),
    (3, 6, 3, 32, 384, 384),      # non-divisible block -> full
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, dk, s, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, dk), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dk), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dk), dtype)
    length = jnp.int32(s - s // 4)
    out = decode_attention(q, k, v, length, block_s=blk,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=tol_for(dtype), rtol=tol_for(dtype))


@pytest.mark.parametrize("s,blk", [
    (300, 128),      # 300 % 128 != 0 -> pad to 384, 3 blocks
    (520, 512),      # just past one block -> pad to 1024
    (129, 64),       # one token over -> pad to 192
    (96, 128),       # shorter than a block -> single s-sized block
])
def test_decode_attention_odd_lengths_no_block_cliff(s, blk):
    """Regression: cache lengths off the block grid used to collapse
    the kernel to a single (s, head_dim) VMEM tile; they must instead
    pad to the next block multiple and still match the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(s + blk), 3)
    q = jax.random.normal(ks[0], (2, 4, 64))
    k = jax.random.normal(ks[1], (2, s, 2, 64))
    v = jax.random.normal(ks[2], (2, s, 2, 64))
    length = jnp.int32(s - 7)
    out = decode_attention(q, k, v, length, block_s=blk,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_odd_length_uses_multiple_blocks():
    """The padded path must genuinely tile: with s > block_s and
    s % block_s != 0 the grid sees ceil(s/block) blocks, not one
    s-sized block (the VMEM-cliff shape)."""
    from unittest import mock
    import repro.kernels.decode_attention as da
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 64))
    k = jax.random.normal(ks[1], (1, 300, 2, 64))
    v = jax.random.normal(ks[2], (1, 300, 2, 64))
    grids = []
    real_call = da.pl.pallas_call

    def spy(kernel, *a, grid=None, **kw):
        grids.append(grid)
        return real_call(kernel, *a, grid=grid, **kw)

    with mock.patch.object(da.pl, "pallas_call", side_effect=spy):
        da.decode_attention.__wrapped__(q, k, v, jnp.int32(250),
                                        block_s=128, interpret=True)
    assert grids and grids[0][2] == 3      # 300 -> 384 = 3 x 128


def test_decode_attention_respects_length():
    """Entries past `length` must not affect the output."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (1, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out1 = decode_attention(q, k, v, jnp.int32(100), block_s=128,
                            interpret=True)
    k2 = k.at[:, 100:].set(jax.random.normal(ks[3], (1, 156, 2, 64)))
    out2 = decode_attention(q, k2, v, jnp.int32(100), block_s=128,
                            interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ----------------------------------------------------------------------
# selective scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,s,d,n,bd,ck", [
    (1, 128, 64, 8, 64, 64),
    (2, 256, 128, 16, 64, 128),
    (2, 512, 256, 16, 256, 256),
    (1, 96, 48, 4, 48, 96),       # non-divisible fallbacks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_sweep(b, s, d, n, bd, ck, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 5)
    x = (jax.random.normal(ks[0], (b, s, d)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
          * 0.1).astype(dtype)
    a_log = jax.random.normal(ks[2], (d, n)) * 0.3
    b_in = jax.random.normal(ks[3], (b, s, n)).astype(dtype)
    c_in = jax.random.normal(ks[4], (b, s, n)).astype(dtype)
    y, h = selective_scan(x, dt, a_log, b_in, c_in, block_d=bd,
                          chunk=ck, interpret=True)
    yr, hr = ref.selective_scan_ref(x, dt, a_log, b_in, c_in)
    np.testing.assert_allclose(y.astype(jnp.float32),
                               yr.astype(jnp.float32),
                               atol=tol_for(dtype) * 5,
                               rtol=tol_for(dtype) * 5)
    np.testing.assert_allclose(h, hr, atol=1e-4, rtol=1e-3)


# ----------------------------------------------------------------------
# rglru scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,s,w,bw,ck", [
    (1, 128, 128, 128, 128),
    (2, 256, 256, 128, 128),
    (2, 384, 96, 96, 192),
])
def test_rglru_scan_sweep(b, s, w, bw, ck):
    ks = jax.random.split(jax.random.PRNGKey(s + w), 2)
    a = jax.random.uniform(ks[0], (b, s, w), minval=0.7, maxval=0.999)
    u = jax.random.normal(ks[1], (b, s, w)) * 0.1
    hs, hf = rglru_scan(a, u, block_w=bw, chunk=ck, interpret=True)
    hsr, hfr = ref.rglru_scan_ref(a, u)
    np.testing.assert_allclose(hs, hsr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hf, hfr, atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------
# fused swiglu
# ----------------------------------------------------------------------
@pytest.mark.parametrize("t,d,f,bt,bf", [
    (128, 64, 256, 64, 128),
    (256, 128, 512, 128, 256),
    (64, 96, 192, 64, 192),
    (100, 64, 250, 100, 250),     # non-divisible fallbacks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_swiglu_sweep(t, d, f, bt, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(t + f), 4)
    x = (jax.random.normal(ks[0], (t, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (f, d)) * 0.05).astype(dtype)
    out = fused_swiglu(x, wg, wu, wd, block_t=bt, block_f=bf,
                       interpret=True)
    want = ref.fused_swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=tol_for(dtype),
                               rtol=tol_for(dtype) * 10)


# ----------------------------------------------------------------------
# int8 quant decode (dense cache)
# ----------------------------------------------------------------------
def _quantized(key, shape):
    from repro.models.attention import quantize_kv
    x = jax.random.normal(key, shape)
    return quantize_kv(x)


@pytest.mark.parametrize("b,h,kv,dk,s,blk", [
    (1, 4, 4, 64, 256, 128),      # MHA
    (2, 8, 2, 128, 512, 128),     # GQA
    (2, 8, 1, 64, 512, 256),      # MQA
    (3, 6, 3, 32, 384, 384),      # non-divisible block -> full
])
def test_decode_attention_quant_sweep(b, h, kv, dk, s, blk):
    from repro.kernels.decode_attention_quant import (
        decode_attention_quant as kernel)
    from repro.models.attention import decode_attention_quant as oracle
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, dk))
    kq, kscale = _quantized(ks[1], (b, s, kv, dk))
    vq, vscale = _quantized(ks[2], (b, s, kv, dk))
    length = jnp.int32(s - s // 4)
    out = kernel(q, kq, kscale, vq, vscale, length, block_s=blk,
                 interpret=True)
    want = oracle(q, kq, kscale, vq, vscale, jnp.arange(s),
                  length - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_quant_respects_length():
    """Stale int8 codes and scales past `length` (recycled cache rows)
    must not affect the output — the kernel masks by position, not by
    page contents."""
    from repro.kernels.decode_attention_quant import (
        decode_attention_quant as kernel)
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (1, 4, 64))
    kq, kscale = _quantized(ks[1], (1, 256, 2, 64))
    vq, vscale = _quantized(ks[2], (1, 256, 2, 64))
    out1 = kernel(q, kq, kscale, vq, vscale, jnp.int32(100),
                  block_s=128, interpret=True)
    kq2 = kq.at[:, 100:].set(127)
    ks2 = kscale.at[:, 100:].set(1e6)
    out2 = kernel(q, kq2, ks2, vq, vscale, jnp.int32(100),
                  block_s=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ----------------------------------------------------------------------
# int8 quant decode (paged cache)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,dk,ps,nb", [
    (1, 4, 4, 64, 16, 4),         # MHA
    (2, 8, 2, 128, 8, 6),         # GQA
    (2, 8, 1, 64, 32, 3),         # MQA
    (3, 6, 3, 32, 8, 5),
])
def test_paged_decode_attention_quant_sweep(b, h, kv, dk, ps, nb):
    from repro.kernels.paged_decode_attention_quant import (
        paged_decode_attention_quant as kernel)
    ks = jax.random.split(jax.random.PRNGKey(b * ps + nb), 3)
    n_pages = b * nb + 2
    q = jax.random.normal(ks[0], (b, h, dk))
    kq, kscale = _quantized(ks[1], (n_pages, ps, kv, dk))
    vq, vscale = _quantized(ks[2], (n_pages, ps, kv, dk))
    table = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    lengths = jnp.int32(nb * ps) - jnp.arange(b, dtype=jnp.int32) * 5 \
        - 1
    out = kernel(q, kq, kscale, vq, vscale, table, lengths,
                 interpret=True)
    want = ref.paged_decode_attention_quant_ref(
        q, kq, kscale, vq, vscale, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_attention_quant_stale_pages_masked():
    """Bytes past each row's length — including whole recycled pages
    the block table still references — must not affect the output."""
    from repro.kernels.paged_decode_attention_quant import (
        paged_decode_attention_quant as kernel)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, h, kv, dk, ps, nb = 2, 4, 2, 64, 8, 4
    q = jax.random.normal(ks[0], (b, h, dk))
    kq, kscale = _quantized(ks[1], (b * nb, ps, kv, dk))
    vq, vscale = _quantized(ks[2], (b * nb, ps, kv, dk))
    table = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    lengths = jnp.array([ps + 3, 2 * ps], jnp.int32)   # rows mid-page
    out1 = kernel(q, kq, kscale, vq, vscale, table, lengths,
                  interpret=True)
    # poison everything past each row's valid prefix
    stale = jnp.concatenate([table[0, 2:], table[1, 2:]])
    poison_k = kq.at[stale].set(127)
    poison_s = kscale.at[stale].set(1e6)
    poison_k = poison_k.at[table[0, 1], 3:].set(-127)
    poison_s = poison_s.at[table[0, 1], 3:].set(1e6)
    out2 = kernel(q, poison_k, poison_s, vq, vscale, table, lengths,
                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ----------------------------------------------------------------------
# ops dispatch falls back to refs off-TPU
# ----------------------------------------------------------------------
def test_ops_dispatch_cpu_fallback():
    assert jax.default_backend() != "tpu"
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = ops.decode_attention(q, k, v, jnp.int32(64))
    want = ref.decode_attention_ref(q, k, v, jnp.int32(64))
    np.testing.assert_allclose(out, want, atol=1e-6)


def test_ops_quant_dispatch_cpu_is_bitwise_oracle():
    """The serving determinism contract: off-TPU, the quant ops
    dispatch to the jnp oracles bit-for-bit (the Pallas kernels are
    the TPU deployment path; CPU must be *identical* to the reference
    the bit-equivalence tests are built on)."""
    assert jax.default_backend() != "tpu"
    from repro.models.attention import decode_attention_quant as oracle
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, h, kv, dk, s = 2, 8, 2, 64, 128
    q = jax.random.normal(ks[0], (b, h, dk))
    kq, kscale = _quantized(ks[1], (b, s, kv, dk))
    vq, vscale = _quantized(ks[2], (b, s, kv, dk))
    out = ops.decode_attention_quant(q, kq, kscale, vq, vscale,
                                     jnp.int32(100))
    want = oracle(q, kq, kscale, vq, vscale, jnp.arange(s),
                  jnp.int32(99))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    ps, nb = 8, 4
    kpq, kps_ = _quantized(ks[1], (b * nb, ps, kv, dk))
    vpq, vps_ = _quantized(ks[2], (b * nb, ps, kv, dk))
    table = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    lengths = jnp.array([ps + 3, 2 * ps], jnp.int32)
    pout = ops.paged_decode_attention_quant(
        q, kpq, kps_, vpq, vps_, table, lengths)
    pwant = ref.paged_decode_attention_quant_ref(
        q, kpq, kps_, vpq, vps_, table, lengths)
    np.testing.assert_array_equal(np.asarray(pout), np.asarray(pwant))
