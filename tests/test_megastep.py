"""Device-resident megastep decode: K fused ticks must be a pure
performance knob.

Three layers of proof, mirroring the repo's equivalence style:

* **sampler-level property tests** (via ``tests/_propshim.py``): one
  ``decode_megastep_rows(n_ticks=K)`` launch emits the exact (K, B)
  emit/done stacks — and the exact next-token logits — that K
  sequential ``decode_step_rows`` launches with host round-trips
  produce, over random initial done bits, heterogeneous step offsets
  and a randomised EOS id so rows finish at every offset in [0, K);
* **engine-level stream equality**: ``run_stepped`` with megastep K
  in {4, 16} emits identical per-task outputs to K=1, with identical
  ``KVStats`` page high-water (all-twin ensemble: every route
  releases its sample tails before member tails allocate, so pool
  usage never exceeds the probe plateau on either path) and leak-free
  mid-megastep retirement page hygiene;
* **transfer-counter hook**: host<->device transfers per emitted
  token drop K-fold at megastep K (the per-tick logits round-trip is
  gone; only (K, B) token ids + done bits cross per megastep).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies
except ImportError:                                # pragma: no cover
    from _propshim import given, settings, strategies

from repro.data.tasks import Task


# ----------------------------------------------------------------------
# sampler-level fixtures: a real tiny paged model + raw page state
# ----------------------------------------------------------------------
_MODELS = {}


def _tiny_model(dtype="float32"):
    import jax
    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    if dtype not in _MODELS:
        cfg = get_config("smollm-135m", reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype=dtype,
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        _MODELS[dtype] = (cfg, prm)
    return _MODELS[dtype]


def _page_state(cfg, b, cache_len, page_size=8):
    """Zeroed paged KV plus disjoint per-row block tables."""
    from repro.serving.kv_pool import PagedKVServer, pages_for
    nb = pages_for(cache_len, page_size)
    srv = PagedKVServer(cfg, page_size=page_size,
                        prefix_cache_entries=0)
    srv.ensure_capacity_stream(b, page_size, 1, cache_len)
    tables = np.stack([srv.pool.alloc(nb) for _ in range(b)])
    return srv, tables


def _row_keys(b):
    import jax
    from repro.sampling import sampler as S
    base = jax.random.PRNGKey(0)
    return np.stack([np.asarray(S.probe_row_keys(base, [a], 1))[0]
                     for a in range(b)])


def _run_both(cfg, prm, k_ticks, b, seed, eos_id, dtype=np.float32,
              temperature=1.0, p_done=0.25):
    """One megastep vs K sequential per-tick launches from identical
    state; returns the two (emits, dones, next_logits) triples."""
    import jax.numpy as jnp
    from repro.data import tokenizer as tok
    from repro.sampling import sampler as S

    rng = np.random.default_rng(seed)
    steps0 = rng.integers(0, 4, b).astype(np.int32)
    done0 = rng.random(b) < p_done
    cache_len = 4 + k_ticks                 # no pos overflow baseline
    srv_a, tables = _page_state(cfg, b, cache_len)
    srv_b, _ = _page_state(cfg, b, cache_len)
    logits0 = jnp.asarray(
        rng.standard_normal((b, tok.VOCAB_SIZE)).astype(dtype))
    keys = _row_keys(b)
    pos0 = steps0.copy()                    # empty prompt: pos == steps

    common = dict(cache_len=cache_len, temperature=temperature,
                  eos_id=eos_id, pad_id=tok.PAD)
    emits_m, dones_m, lg_m, _ = S.decode_megastep_rows(
        cfg, prm, logits0, srv_a.pages,
        jnp.asarray(tables), jnp.asarray(pos0), jnp.asarray(keys),
        jnp.asarray(steps0), jnp.asarray(done0), n_ticks=k_ticks,
        **common)

    lg, pages = logits0, srv_b.pages
    done = jnp.asarray(done0)
    emits_s, dones_s = [], []
    for t in range(k_ticks):
        (emit, _lp, _lv, done, lg, pages) = S.decode_step_rows(
            cfg, prm, lg, pages, jnp.asarray(tables),
            jnp.asarray(pos0 + t), jnp.asarray(keys),
            jnp.asarray(steps0 + t), done, **common)
        emits_s.append(np.asarray(emit))
        dones_s.append(np.asarray(done))
    return ((np.asarray(emits_m), np.asarray(dones_m),
             np.asarray(lg_m)),
            (np.stack(emits_s), np.stack(dones_s), np.asarray(lg)))


@settings(max_examples=12)
@given(strategies.sampled_from([1, 4, 16]),
       strategies.integers(min_value=0, max_value=10_000),
       strategies.integers(min_value=3, max_value=18))
def test_megastep_bit_equals_sequential_ticks(k_ticks, seed, eos_id):
    """The fused scan and K host-driven per-tick launches emit the
    exact same token/done stacks and end with the exact same pending
    logits — rows entering done, finishing mid-megastep at random
    offsets (random EOS id), and heterogeneous step offsets
    included."""
    cfg, prm = _tiny_model()
    (em, dm, lm), (es, ds, ls) = _run_both(
        cfg, prm, k_ticks, b=4, seed=seed, eos_id=eos_id)
    np.testing.assert_array_equal(em, es)
    np.testing.assert_array_equal(dm, ds)
    np.testing.assert_array_equal(lm, ls)


def test_megastep_rows_finish_at_every_offset():
    """Coverage guarantee for the property above: across a seeded
    sweep, rows are observed finishing (done flipping) at *every*
    offset in [0, K) of a K=4 megastep — and every example is
    bit-equivalent."""
    cfg, prm = _tiny_model()
    k_ticks = 4
    offsets_seen = set()
    for seed in range(64):
        (em, dm, _), (es, ds, _) = _run_both(
            cfg, prm, k_ticks, b=4, seed=1_000 + seed,
            eos_id=3 + (seed % 12), p_done=0.0)
        np.testing.assert_array_equal(em, es)
        np.testing.assert_array_equal(dm, ds)
        # every row starts live, so a True in dones[t] with False in
        # dones[t-1] is exactly an EOS at megastep offset t
        flipped = dm & ~np.concatenate(
            [np.zeros((1, dm.shape[1]), bool), dm[:-1]])
        for t in range(k_ticks):
            if flipped[t].any():
                offsets_seen.add(t)
        if offsets_seen == set(range(k_ticks)):
            break
    assert offsets_seen == set(range(k_ticks)), \
        f"EOS offsets covered: {sorted(offsets_seen)}"


def test_megastep_preserves_bf16_lane_dtype():
    """Mixed-dtype satellite: under a bf16 model the lane state stays
    bf16 end-to-end (the old per-tick host pull silently widened to
    float32) and the megastep still bit-equals the per-tick path."""
    import jax.numpy as jnp
    cfg, prm = _tiny_model("bfloat16")
    (em, dm, lm), (es, ds, ls) = _run_both(
        cfg, prm, 4, b=2, seed=7, eos_id=6, dtype=jnp.bfloat16)
    assert lm.dtype == jnp.bfloat16
    assert ls.dtype == jnp.bfloat16
    np.testing.assert_array_equal(em, es)
    np.testing.assert_array_equal(dm, ds)
    np.testing.assert_array_equal(
        lm.astype(np.float32), ls.astype(np.float32))


def test_planner_validates_megastep():
    from repro.serving.scheduler import StepPlanner
    with pytest.raises(ValueError):
        StepPlanner(megastep=0)
    assert StepPlanner(megastep=16).megastep == 16


# ----------------------------------------------------------------------
# engine-level: K is invisible in every judge-visible output AND in
# the KV high-water / page hygiene
# ----------------------------------------------------------------------
def _twin_zoo(seed=0):
    """Probe + three probe-twin members: every escalated member
    decodes on the probe's server from reused prompt pages, so each
    row's page usage peaks at its probe plateau — making the KV
    high-water provably K-invariant."""
    import jax
    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.models import params as params_lib
    from repro.serving import ZooModel
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    prm = params_lib.init_params(cfg, jax.random.PRNGKey(seed))
    probe = ZooModel(name="probe", cfg=cfg, params=prm)
    ensemble = [ZooModel(name=f"twin{i}", cfg=cfg, params=prm)
                for i in range(3)]
    return probe, ensemble


def _twin_tasks(n):
    return [Task(task_id=f"m{i}", benchmark="x", kind="math",
                 text=f"{i % 10} {(i * 7) % 10} + 1 = ", gold="0",
                 difficulty=0.0) for i in range(n)]


def _run_twin(megastep, n_tasks=8, max_new=6, temp=1.2, seed=0):
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    probe, ensemble = _twin_zoo(seed)
    eng = BatchedACAREngine(
        ACARConfig(probe_temperature=temp, seed=seed), probe,
        ensemble, max_new_tokens=max_new, kv_prefix_cache=0)
    res = eng.run_stepped(
        _twin_tasks(n_tasks),
        MicroBatchPolicy(max_batch_size=n_tasks,
                         max_batch_tokens=1 << 20),
        chunk_tokens=4, max_active_rows=n_tasks, megastep=megastep)
    return eng, res


@pytest.mark.slow
def test_megastep_engine_streams_and_highwater_k_invariant():
    """K in {4, 16} vs the per-tick baseline: identical sigma, modes,
    probe texts, member answers and final answers; identical KV page
    high-water; and mid-megastep retirement leaves zero pages behind
    (only scratch survives — the prefix cache is disabled)."""
    base_eng, base = _run_twin(megastep=1)
    hw0 = base_eng.kv_stats()["probe"].pages_highwater
    for k in (4, 16):
        eng, res = _run_twin(megastep=k)
        np.testing.assert_array_equal(base.sigma, res.sigma)
        np.testing.assert_array_equal(base.modes, res.modes)
        assert base.final_answers == res.final_answers
        assert base.probe_texts == res.probe_texts
        assert base.member_answers == res.member_answers
        # identical page high-water: megastep may hold a finished
        # lane's pages <= K-1 ticks longer, but usage never exceeds
        # the probe plateau either way (all-twin ensemble)
        assert eng.kv_stats()["probe"].pages_highwater == hw0
        # mid-megastep retirement page hygiene
        for srv in eng._kv_servers.values():
            assert srv.pool.pages_in_use == srv._scratch.size
        # megastep really fused: fewer launches than ticks advanced,
        # and mid-megastep finishes burned masked steps
        assert res.step.launches < base.step.launches
        assert res.step.masked_decode_steps > 0
        assert res.step.decode_tokens == base.step.decode_tokens


@pytest.mark.slow
def test_megastep_transfers_per_token_drop_k_fold():
    """The transfer-counter hook: with greedy probes (no early EOS),
    mode-0 routing and every row admitted at tick 0 in lockstep,
    megastep K=16 serves the same decode tokens in exactly 16x fewer
    decode launches — so host<->device transfer events per emitted
    token drop exactly K-fold."""
    from repro.configs.acar import ACARConfig
    from repro.serving import BatchedACAREngine, MicroBatchPolicy
    from repro.serving.metrics import PromCounters
    from repro.serving.queue import AdmissionQueue
    from repro.serving.scheduler import StepPlanner
    from repro.serving.step_loop import StepLoopRunner

    def run(megastep):
        probe, ensemble = _twin_zoo(0)
        eng = BatchedACAREngine(
            ACARConfig(probe_temperature=0.0, seed=0), probe,
            ensemble, max_new_tokens=16, kv_prefix_cache=0,
            route_fn=lambda sig, idx: np.zeros(len(idx), np.int32))
        # max_batch_size=1: the queue is ready the instant any request
        # has arrived, so the admission loop pulls all four rows at
        # tick 0 and they decode in lockstep on both paths
        queue = AdmissionQueue(MicroBatchPolicy(
            max_batch_size=1, max_batch_tokens=1 << 20))
        for t in _twin_tasks(4):
            queue.submit(t, arrival_time=0)
        runner = StepLoopRunner(
            eng, queue, StepPlanner(chunk_tokens=4, max_active_rows=4,
                                    megastep=megastep),
            PromCounters())
        return runner.run()

    r1, r16 = run(1), run(16)
    assert r1.decode_tokens == r16.decode_tokens > 0
    rate1 = (r1.decode_h2d + r1.decode_d2h) / r1.decode_tokens
    rate16 = (r16.decode_h2d + r16.decode_d2h) / r16.decode_tokens
    assert rate1 == pytest.approx(16 * rate16), \
        f"per-token transfer rate {rate1} vs {rate16}"
    assert r16.masked_decode_steps == 0         # greedy: no early EOS
