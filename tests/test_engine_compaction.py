"""Compacted <-> masked engine equivalence (the tentpole contract).

The escalated-subset engine must be an execution strategy, not a
semantic change: identical sigma, modes, final answers, per-member
answers, and trace record hashes as the masked full-batch path, at any
escalation rate and for batch sizes on and off the power-of-two bucket
boundaries — while actually decoding fewer ensemble rows.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from harness.simulate import run_engine_compaction_equivalence

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


def forced_route(rate: float):
    """route_fn driving an exact escalation rate: the first
    round(rate*B) rows alternate arena_lite / full_arena, the rest stay
    single_agent."""
    def route(sig):
        b = sig.shape[0]
        modes = np.zeros(b, np.int32)
        k = int(round(rate * b))
        for j in range(k):
            modes[j] = 1 + (j % 2)
        return jnp.asarray(modes)
    return route


@pytest.mark.parametrize("batch_size", [6, 8])
@pytest.mark.parametrize("rate", [0.0, 0.5, 1.0])
def test_compaction_equivalence_forced_rates(rate, batch_size,
                                             tmp_path):
    """Escalation 0% / ~50% / 100%, batch sizes straddling the
    power-of-two bucket boundary (6 pads into a 4+2 split world,
    8 is exact)."""
    report = run_engine_compaction_equivalence(
        n_tasks=batch_size, batch_size=batch_size,
        route_fn=forced_route(rate),
        workdir=tmp_path / f"r{rate}-b{batch_size}")
    assert report.ok, report.summary()
    # probe prefill is always shared-prefix: N=3 -> 3x
    assert report.probe_prefill_reduction == pytest.approx(3.0)
    if rate == 0.0:
        # nothing escalated: neither path decodes any ensemble rows
        assert report.ensemble_decode_token_reduction == 1.0
    elif rate == 0.5:
        # half the rows escalated -> compaction at least halves the
        # ensemble decode tokens of the masked path
        assert report.ensemble_decode_token_reduction >= 1.5
    else:
        # every row escalated, but only half to the full arena: the
        # arena-lite members run the full batch while the third member
        # still compacts its modes>=2 subset — a modest, real win
        assert 1.0 <= report.ensemble_decode_token_reduction <= 1.5


def test_compaction_all_full_arena_saves_nothing(tmp_path):
    """All rows at sigma=1: every member decodes every row; compaction
    must not cheat (and must still be bit-equivalent)."""
    def route(sig):
        return jnp.full(sig.shape[0], 2, jnp.int32)

    report = run_engine_compaction_equivalence(
        n_tasks=8, batch_size=8, route_fn=route,
        workdir=tmp_path)
    assert report.ok, report.summary()
    assert report.ensemble_decode_token_reduction == pytest.approx(1.0)


def test_compaction_equivalence_emergent_routing(tmp_path):
    """No forced routing: whatever sigma the tiny probe produces, the
    two paths must agree bit-for-bit (including the audit chain
    head) across multiple micro-batches."""
    report = run_engine_compaction_equivalence(
        n_tasks=12, batch_size=5, workdir=tmp_path)
    assert report.ok, report.summary()


def test_compaction_saves_decode_tokens_at_paper_rate(tmp_path):
    """At the paper's ~45.8% escalation the compacted engine must cut
    ensemble decode tokens >= 2x vs the masked path."""
    # 8-row batches: 4 escalated rows (2 lite + 2 full) ~ 50%
    report = run_engine_compaction_equivalence(
        n_tasks=16, batch_size=8, route_fn=forced_route(0.458),
        workdir=tmp_path)
    assert report.ok, report.summary()
    assert report.ensemble_decode_token_reduction >= 2.0
