"""Quickstart: sigma-based routing with auditable traces in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs ACAR (Alg. 1) over a handful of tasks with the calibrated
synthetic model pool, prints each routing decision, and verifies the
hash-chained artifact store.
"""
import tempfile
from pathlib import Path

from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.core.sigma import sigma
from repro.data.tasks import paper_suite
from repro.teamllm.artifacts import ArtifactStore


def main():
    # 1. sigma by hand (paper Def. 1)
    print("sigma(['42','42','42']) =", sigma(["42", "42", "42"]))
    print("sigma(['42','42','17']) =", sigma(["42", "42", "17"]))
    print("sigma(['42','17','99']) =", sigma(["42", "17", "99"]))

    # 2. full ACAR over tasks, with immutable decision traces
    backends = paper_backends()
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(Path(d) / "runs.jsonl")
        orch = ACAROrchestrator(
            ACARConfig(seed=0),
            probe=backends["gemini-2.0-flash"],
            ensemble=backends,
            store=store,
            run_id="quickstart")
        tasks = paper_suite(seed=0)[::130][:12]  # mix of benchmarks
        print(f"\n{'task':18s} {'sigma':>5s} {'mode':>12s} "
              f"{'models':>7s} {'correct':>7s}")
        for t in tasks:
            out = orch.run_task(t)
            tr = out.trace
            print(f"{t.task_id:18s} {tr.sigma:5.1f} {tr.mode:>12s} "
                  f"{len(tr.responses):7d} {str(out.correct):>7s}")

        audit = store.audit()
        print(f"\nartifact store: {audit['records']} records, "
              f"parse errors {audit['parse_errors']}, "
              f"chain head {audit['head'][:16]}…")
        saved = sum(3 - len(o["responses"])
                    for o in store.read_all())
        print(f"ensemble calls saved vs always-full-arena: {saved} "
              f"of {3 * len(tasks)}")


if __name__ == "__main__":
    main()
