"""End-to-end driver (the paper's kind: serving/orchestration).

Trains a 4-model zoo of reduced architectures on char-level arithmetic,
then serves a batch of tasks through the batched ACAR engine: (B x 3)
probe decode -> EXTRACT -> on-device sigma/routing -> masked ensemble
decodes -> vectorised judge — the TPU-native formulation of Alg. 1.

With ``--scheduler`` the request stream is admitted through the
continuous-batching queue and served as micro-batches, printing the
Prometheus-style scheduler counters at the end. With ``--step-loop``
it runs the step-level loop instead (streaming admission off
``AdmissionQueue.ready()``, chunked prefill, mixed-phase decode steps,
mid-stream retirement) — bit-identical answers, different execution.
With ``--shards N`` the step loop runs on a data-sharded serving mesh
(per-shard paged KV pools, least-loaded placement, one shard_map'd
program per tick) — still bit-identical answers; this example forces
the host device count so it works on a plain CPU. With ``--megastep K``
the step loop fuses up to K decode ticks into one device-resident
launch (lane logits never touch the host between ticks) — again
bit-identical answers, just fewer launches and host round-trips.

Fleet selection uses registry arch names with optional page-layout
variant suffixes (``arch:quant`` int8 KV pages, ``arch:swaN`` ring
pages); ``--hetero-fleet`` serves the paper's headline mix (Mamba
probe + quant and sliding-window members + a full-attention arena
member) through the stepped engine's heterogeneous page layouts.

    PYTHONPATH=src python examples/serve_acar.py [--tasks 32]
        [--train-steps 300] [--scheduler | --step-loop | --shards 4]
        [--megastep 16] [--batch-size 8]
        [--probe ARCH[:quant|:swaN]] [--ensemble SPEC ...]
        [--hetero-fleet]
"""
import argparse

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--scheduler", action="store_true")
    ap.add_argument("--step-loop", action="store_true")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--megastep", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--probe", default=None)
    ap.add_argument("--ensemble", nargs="+", default=None)
    ap.add_argument("--hetero-fleet", action="store_true")
    args = ap.parse_args()
    if args.shards:
        # must happen before the first jax backend init (merges into
        # any user-set XLA_FLAGS; an existing count wins)
        from repro.xla_flags import force_host_device_count
        force_host_device_count(args.shards)
    from repro.launch.serve import main as serve_main
    argv = ["--tasks", str(args.tasks),
            "--train-steps", str(args.train_steps),
            "--batch-size", str(args.batch_size)]
    if args.scheduler:
        argv.append("--scheduler")
    if args.step_loop:
        argv.append("--step-loop")
    if args.shards:
        argv.extend(["--shards", str(args.shards)])
    if args.megastep != 1:
        argv.extend(["--megastep", str(args.megastep)])
    if args.probe:
        argv.extend(["--probe", args.probe])
    if args.ensemble:
        argv.extend(["--ensemble"] + args.ensemble)
    if args.hetero_fleet:
        argv.append("--hetero-fleet")
    serve_main(argv)
