"""Training driver example: train a zoo model on the arithmetic corpus
and sample from it.

    PYTHONPATH=src python examples/train_model.py            # ~1M params
    PYTHONPATH=src python examples/train_model.py --size 100m  # ~100M

``--size 100m`` uses the real smollm-135m stack (30L x 576) with the
char-level vocabulary (~80M backbone parameters) — a few hundred steps
on CPU takes a while but exercises the full-scale training path.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.launch.train import train
from repro.models import params as params_lib
from repro.sampling import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.size == "100m":
        cfg = get_config("smollm-135m").replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True, name="smollm-arith-100m")
        params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        n = params_lib.count_params(params)
        print(f"training {cfg.name}: {n / 1e6:.1f}M params")
        # train() with reduced=False uses the full config; smaller batch
        # keeps a CPU step tractable.
        cfg, params, _ = _train_full(cfg, args.steps)
    else:
        cfg, params, _ = train(arch="smollm-135m", data="arithmetic",
                               steps=args.steps, batch=64, seq=24,
                               lr=2e-3, ckpt=args.ckpt)

    # sample: ask the model some sums
    prompts = ["3 + 4 = ", "9 - 5 = ", "7 + 8 = ", "2 - 6 = "]
    ids = jnp.asarray(tok.encode_batch(prompts, 12))
    out = generate(cfg, params, ids, max_new_tokens=6,
                   temperature=0.0, eos_id=tok.EOS, pad_id=tok.PAD)
    for p, row in zip(prompts, np.asarray(out.tokens)):
        print(f"  {p!r} -> {tok.decode(row)!r}")


def _train_full(cfg, steps):
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import arithmetic_batches
    from repro.launch.steps import make_train_step
    from repro.models import params as P
    from repro import optim
    import time

    tc = TrainConfig(learning_rate=1e-3, warmup_steps=50,
                     total_steps=steps)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.init(params)
    step = jax.jit(make_train_step(cfg, tc))
    it = arithmetic_batches(8, 24, seed=0)
    t0 = time.perf_counter()
    m = {}
    for i in range(steps):
        b = next(it)
        params, opt_state, m = step(params, opt_state, {
            "tokens": jnp.asarray(b.tokens),
            "labels": jnp.asarray(b.labels),
            "loss_mask": jnp.asarray(b.loss_mask)})
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({(time.perf_counter() - t0) / (i + 1):.2f}s/step)")
    return cfg, params, m


if __name__ == "__main__":
    main()
