"""Attribution demo (paper §6.3): ground-truth counterfactuals vs
proxy signals on live ACAR runs.

    PYTHONPATH=src python examples/attribution_demo.py
"""
from repro.configs.acar import ACARConfig
from repro.core.attribution import (
    leave_one_out, proxy_agreement, proxy_entropy, proxy_similarity,
    shapley)
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.data.tasks import paper_suite


def main():
    backends = paper_backends()
    orch = ACAROrchestrator(ACARConfig(seed=0),
                            backends["gemini-2.0-flash"], backends,
                            run_id="attr-demo")
    shown = 0
    for t in paper_suite(seed=0)[310:]:  # mixed benchmarks
        if t.benchmark == "livecodebench":
            continue
        out = orch.run_task(t)
        tr = out.trace
        if tr.mode != "full_arena":
            continue
        gold = t.gold.lower() if t.kind == "reasoning" else t.gold
        loo = leave_one_out(tr.responses, tr.task_id, gold)
        phi = shapley(tr.responses, tr.task_id, gold)
        agree = proxy_agreement(tr.responses)
        ent = proxy_entropy(tr.responses)
        sim = proxy_similarity(tr.responses, tr.final_answer)
        print(f"\n{t.task_id} ({t.benchmark}) correct={out.correct}")
        print(f"  {'model':18s} {'LOO':>7s} {'Shapley':>8s} "
              f"{'agree':>6s} {'entropy':>8s} {'sim':>6s}")
        for r in tr.responses:
            print(f"  {r.model:18s} {loo[r.model]:7.3f} "
                  f"{phi[r.model]:8.3f} {agree[r.model]:6.2f} "
                  f"{ent[r.model]:8.3f} {sim[r.model]:6.3f}")
        shown += 1
        if shown >= 5:
            break
    print("\nGround truth (LOO/Shapley) requires explicit "
          "counterfactual judge re-runs; the proxy columns do not "
          "track it — the paper's §6.3 finding. Run "
          "benchmarks/attribution_bench.py for the full correlation "
          "study.")


if __name__ == "__main__":
    main()
